"""Optimizers: convergence on a quadratic, 8-bit ~= fp32, adafactor
state shapes, clipping."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.optimizer import make_optimizer


def _quadratic_params(rng):
    return {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((8,)), jnp.float32)}


def _loss(params):
    return jnp.sum(params["w"] ** 2) + jnp.sum((params["b"] - 1.0) ** 2)


@pytest.mark.parametrize("kind", ["adamw", "adamw8bit", "adafactor"])
def test_optimizer_decreases_loss(kind, rng):
    init, update = make_optimizer(kind, lr=5e-2, weight_decay=0.0)
    params = _quadratic_params(rng)
    state = init(params)
    l0 = float(_loss(params))
    for _ in range(60):
        grads = jax.grad(_loss)(params)
        params, state = update(grads, state, params)
    assert float(_loss(params)) < 0.05 * l0


def test_8bit_tracks_fp32(rng):
    params = _quadratic_params(rng)
    i32, u32 = make_optimizer("adamw", lr=1e-2, weight_decay=0.0)
    i8, u8 = make_optimizer("adamw8bit", lr=1e-2, weight_decay=0.0)
    p32, s32 = params, i32(params)
    p8, s8 = params, i8(params)
    for _ in range(25):
        g32 = jax.grad(_loss)(p32)
        p32, s32 = u32(g32, s32, p32)
        g8 = jax.grad(_loss)(p8)
        p8, s8 = u8(g8, s8, p8)
    # trajectories stay close (the compression is nearly lossless here)
    diff = float(jnp.max(jnp.abs(p32["w"] - p8["w"])))
    scale = float(jnp.max(jnp.abs(p32["w"]))) + 1e-6
    assert diff / scale < 0.15


def test_adafactor_state_is_factored(rng):
    params = _quadratic_params(rng)
    init, _ = make_optimizer("adafactor")
    state = init(params)
    vr, vc = state.v["w"]
    assert vr.shape == (8,) and vc.shape == (8,)
    assert state.m["w"].dtype == jnp.bfloat16   # compressed first moment


def test_clipping_bounds_update(rng):
    init, update = make_optimizer("adamw", lr=1.0, clip_norm=1e-3,
                                  weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = init(params)
    huge = {"w": jnp.full((4,), 1e9, jnp.float32)}
    new_params, _ = update(huge, state, params)
    assert float(jnp.max(jnp.abs(new_params["w"]))) < 20.0
