"""Gram-tile hot path (DESIGN.md §8): oracle-parity for the cross-pair
``xmv_gram_tile`` kernel (per-axis packs, (Bi, nt, Bj) grid) against
``mgk_direct``/``xmv_gram_full`` AND the per-pair row-panel kernel,
covering ragged Bi != Bj tiles, ragged n != m pads, zero-octile rows,
both contraction modes, the fused epilogue, and the single-launch jaxpr;
plus convergence-segmented PCG pinned iterate-for-iterate against masked
lockstep with strictly fewer pair-matvec evaluations."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.base_kernels import KroneckerDelta, SquareExponential
from repro.core.graph import batch_from_graphs
from repro.core.mgk import mgk_pairs_sparse, mgk_pairs_sparse_segmented
from repro.core.pcg import pcg_solve, pcg_solve_segmented
from repro.core.xmv import xmv_gram_full
from repro.data import make_drugbank_like_dataset
from repro.kernels.ops import row_panel_packs_for_batch, \
    stack_row_panel_packs
from repro.kernels.xmv_block_sparse import pack_graph_row_panels, \
    xmv_gram_tile, xmv_row_panel_batched

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)
TOL = dict(rtol=1e-5, atol=1e-5)


def _sparse_pair(rng, n, density=0.08, dead_band=None):
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    if dead_band is not None:
        lo, hi = dead_band
        a[lo:hi, :] = 0.0
        a[:, lo:hi] = 0.0
    e = rng.random((n, n)).astype(np.float32) * (a != 0)
    return a, e


def _axis_packs(graphs, edge_kernel=None):
    """Stack per-graph row-panel packs at the axis-shared k_max."""
    loose = [pack_graph_row_panels(a, e, edge_kernel=edge_kernel)
             for a, e in graphs]
    k_max = max(p.k_max for p in loose)
    return stack_row_panel_packs(
        [pack_graph_row_panels(a, e, edge_kernel=edge_kernel,
                               k_max=k_max) for a, e in graphs])


def _stack(graphs, which):
    return jnp.asarray(np.stack([g[which] for g in graphs]))


@pytest.mark.parametrize("Bi,Bj,n,m", [(3, 5, 32, 48), (4, 2, 40, 40)])
def test_gram_tile_matches_oracle_ragged(rng, Bi, Bj, n, m):
    """Ragged Bi != Bj and n != m cross tiles, both modes, vs the
    doubly-vmapped full-materialization oracle; graph 0 carries
    zero-octile tile-row bands (count = 0 rows)."""
    rows = [_sparse_pair(rng, n, dead_band=(8, 16) if i == 0 else None)
            for i in range(Bi)]
    cols = [_sparse_pair(rng, m, dead_band=(0, 8) if j == 1 else None)
            for j in range(Bj)]
    P = jnp.asarray(rng.random((Bi, Bj, n, m)).astype(np.float32))
    ref = np.asarray(xmv_gram_full(_stack(rows, 0), _stack(rows, 1),
                                   _stack(cols, 0), _stack(cols, 1),
                                   P, EK))
    for mode, ek in (("elementwise", None), ("mxu", EK)):
        p1 = _axis_packs(rows, ek)
        p2 = _axis_packs(cols, ek)
        if mode == "elementwise":
            assert int(np.asarray(p1.count).min()) == 0  # truly empty
        y = xmv_gram_tile(p1, p2, P, EK, mode=mode)
        np.testing.assert_allclose(np.asarray(y), ref, err_msg=mode,
                                   **TOL)


def test_gram_tile_matches_per_pair_kernel(rng):
    """Per-axis Gram-tile execution vs the per-pair row-panel kernel on
    the stacked pair expansion — same values from Bi + Bj packs instead
    of Bi*Bj."""
    Bi, Bj, n = 3, 4, 32
    rows = [_sparse_pair(rng, n) for _ in range(Bi)]
    cols = [_sparse_pair(rng, n) for _ in range(Bj)]
    P = jnp.asarray(rng.random((Bi, Bj, n, n)).astype(np.float32))
    flat_rows = [rows[b // Bj] for b in range(Bi * Bj)]
    flat_cols = [cols[b % Bj] for b in range(Bi * Bj)]
    for mode, ek in (("elementwise", None), ("mxu", EK)):
        y = xmv_gram_tile(_axis_packs(rows, ek), _axis_packs(cols, ek),
                          P, EK, mode=mode)
        yp = xmv_row_panel_batched(_axis_packs(flat_rows, ek),
                                   _axis_packs(flat_cols, ek),
                                   P.reshape(Bi * Bj, n, n), EK,
                                   mode=mode)
        np.testing.assert_allclose(np.asarray(y).reshape(Bi * Bj, n, n),
                                   np.asarray(yp), err_msg=mode, **TOL)


def test_gram_tile_fused_epilogue(rng):
    Bi, Bj, n = 2, 3, 32
    rows = [_sparse_pair(rng, n) for _ in range(Bi)]
    cols = [_sparse_pair(rng, n) for _ in range(Bj)]
    P = jnp.asarray(rng.random((Bi, Bj, n, n)).astype(np.float32))
    diag = jnp.asarray(rng.random(P.shape).astype(np.float32) + 1.0)
    for mode, ek in (("elementwise", None), ("mxu", EK)):
        p1, p2 = _axis_packs(rows, ek), _axis_packs(cols, ek)
        y = xmv_gram_tile(p1, p2, P, EK, mode=mode)
        fused = xmv_gram_tile(p1, p2, P, EK, diag=diag, mode=mode)
        ref = np.asarray(diag) * np.asarray(P) - np.asarray(y)
        np.testing.assert_allclose(np.asarray(fused), ref, err_msg=mode,
                                   **TOL)


def _count_primitive(jaxpr, name):
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                count += _count_primitive(v.jaxpr, name)
            elif isinstance(v, jax.extend.core.Jaxpr):
                count += _count_primitive(v, name)
    return count


def test_gram_tile_is_single_launch(rng):
    """The whole Bi x Bj cross-product matvec must be exactly ONE
    pallas_call — the pair axes ride the grid, not a launch loop."""
    Bi, Bj, n = 3, 4, 32
    rows = [_sparse_pair(rng, n) for _ in range(Bi)]
    cols = [_sparse_pair(rng, n) for _ in range(Bj)]
    P = jnp.asarray(rng.random((Bi, Bj, n, n)).astype(np.float32))
    for mode, ek in (("elementwise", None), ("mxu", EK)):
        p1, p2 = _axis_packs(rows, ek), _axis_packs(cols, ek)
        n_calls = _count_primitive(
            jax.make_jaxpr(
                lambda P: xmv_gram_tile(p1, p2, P, EK, mode=mode)
            )(P).jaxpr, "pallas_call")
        assert n_calls == 1, f"{mode}: traced {n_calls} pallas_calls"


@pytest.fixture(scope="module")
def tile_batches():
    """(row batch [Bi], col batch [Bj], flattened pair batches) of real
    drugbank-like graphs."""
    gs = [g for g in make_drugbank_like_dataset(24, seed=11)
          if 6 <= g.n_nodes <= 40]
    Bi, Bj = 3, 4
    g1u = batch_from_graphs(gs[:Bi], pad_to=40)
    g2u = batch_from_graphs(gs[Bi:Bi + Bj], pad_to=40)
    rep = lambda x: jnp.repeat(x, Bj, axis=0)                   # noqa
    til = lambda x: jnp.tile(x, (Bi,) + (1,) * (x.ndim - 1))    # noqa
    return (Bi, Bj), g1u, g2u, jax.tree.map(rep, g1u), \
        jax.tree.map(til, g2u)


def test_mgk_gram_tile_matches_direct_and_per_pair(tile_batches):
    """mgk_pairs_sparse(gram_tile=...) vs the LAPACK oracle (mgk_direct)
    and the per-pair sparse solve, both modes."""
    from repro.core.graph import Graph
    from repro.core.reference import mgk_direct
    (Bi, Bj), g1u, g2u, g1f, g2f = tile_batches

    def to_graph(gb, b):
        k = int(gb.n_nodes[b])
        return Graph(
            adjacency=np.asarray(gb.adjacency[b])[:k, :k],
            vertex_labels=np.asarray(gb.vertex_labels[b])[:k],
            edge_labels=np.asarray(gb.edge_labels[b])[:k, :k],
            start_prob=np.asarray(gb.start_prob[b])[:k],
            stop_prob=np.asarray(gb.stop_prob[b])[:k])

    direct = np.array([
        mgk_direct(to_graph(g1u, b // Bj), to_graph(g2u, b % Bj), VK, EK)
        for b in range(Bi * Bj)])
    for mode, ek in (("elementwise", None), ("mxu", EK)):
        a1 = row_panel_packs_for_batch(g1u, edge_kernel=ek)
        a2 = row_panel_packs_for_batch(g2u, edge_kernel=ek)
        res = mgk_pairs_sparse(g1f, g2f, a1, a2, VK, EK,
                               sparse_mode=mode, tol=1e-10,
                               gram_tile=(Bi, Bj))
        np.testing.assert_allclose(np.asarray(res.values), direct,
                                   rtol=1e-4, err_msg=mode)
        p1 = row_panel_packs_for_batch(g1f, edge_kernel=ek)
        p2 = row_panel_packs_for_batch(g2f, edge_kernel=ek)
        ref = mgk_pairs_sparse(g1f, g2f, p1, p2, VK, EK,
                               sparse_mode=mode, tol=1e-10)
        np.testing.assert_allclose(np.asarray(res.values),
                                   np.asarray(ref.values), rtol=1e-5,
                                   err_msg=mode)
        assert np.array_equal(np.asarray(res.iterations),
                              np.asarray(ref.iterations))


def test_gram_tile_adjoint_grads_match_per_pair(tile_batches):
    """The adjoint path dispatches to the Gram-tile kernel unchanged:
    per-pair hyperparameter gradients from per-axis packs must match the
    per-pair row-panel gradients."""
    from repro.core.adjoint import kernel_theta, mgk_value_fn
    (Bi, Bj), g1u, g2u, g1f, g2f = tile_batches
    theta = kernel_theta(VK, EK)
    a1 = row_panel_packs_for_batch(g1u, edge_kernel=EK)
    a2 = row_panel_packs_for_batch(g2u, edge_kernel=EK)
    p1 = row_panel_packs_for_batch(g1f, edge_kernel=EK)
    p2 = row_panel_packs_for_batch(g2f, edge_kernel=EK)
    fn_t = mgk_value_fn(g1f, g2f, VK, EK, method="sparse", packs1=a1,
                        packs2=a2, sparse_mode="mxu",
                        gram_tile=(Bi, Bj))
    fn_p = mgk_value_fn(g1f, g2f, VK, EK, method="sparse", packs1=p1,
                        packs2=p2, sparse_mode="mxu")
    vt, gt = fn_t.value_and_pair_grads(theta)
    vp, gp = fn_p.value_and_pair_grads(theta)
    np.testing.assert_allclose(np.asarray(vt), np.asarray(vp), rtol=1e-5)
    for group in gt:
        for name in gt[group]:
            np.testing.assert_allclose(
                np.asarray(gt[group][name]), np.asarray(gp[group][name]),
                rtol=2e-3, atol=2e-6, err_msg=f"{group}.{name}")


# -- convergence-segmented PCG ----------------------------------------------

def _mixed_spd(rng, B, N):
    """SPD batch with deliberately mixed conditioning -> mixed
    convergence (the pair-retirement scenario)."""
    a = rng.random((B, N, N)).astype(np.float32)
    spd = np.einsum("bij,bkj->bik", a, a) + \
        N * np.eye(N, dtype=np.float32)[None]
    for i in range(B // 2):
        spd[i] = np.eye(N, dtype=np.float32) * (i + 2) \
            + 0.01 * spd[i] / N
    return spd


@pytest.mark.parametrize("variant", ["classic", "pipelined"])
@pytest.mark.parametrize("pad_multiple", [1, 4])
def test_segmented_matches_lockstep_iterate_for_iterate(rng, variant,
                                                        pad_multiple):
    B, N = 6, 32
    spd = _mixed_spd(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    spd_j = jnp.asarray(spd)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd_j, p)       # noqa

    def select(lanes):
        sub = spd_j[jnp.asarray(lanes)]
        return lambda p: jnp.einsum("bij,bj->bi", sub, p)

    lock = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-10, max_iter=500,
                     variant=variant)
    seg = pcg_solve_segmented(mv, jnp.asarray(b), diag, tol=1e-10,
                              max_iter=500, segment_size=8,
                              variant=variant, select=select,
                              pad_multiple=pad_multiple)
    # identical per-pair trajectories: same iteration counts, same
    # solutions, same final residuals
    assert np.array_equal(np.asarray(lock.iterations),
                          np.asarray(seg.iterations))
    np.testing.assert_allclose(np.asarray(seg.x), np.asarray(lock.x),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(seg.residual),
                               np.asarray(lock.residual),
                               rtol=1e-5, atol=1e-12)
    assert bool(np.asarray(seg.converged).all())
    # ... at strictly fewer pair-matvec evaluations (mixed convergence)
    assert int(np.asarray(lock.iterations).max()) \
        > int(np.asarray(lock.iterations).min())
    assert int(seg.matvec_pairs) < int(lock.matvec_pairs)


def test_segmented_without_select_matches_lockstep(rng):
    """No ``select`` -> no compaction: results still identical, work
    identical to lockstep (the honesty contract of matvec_pairs)."""
    B, N = 4, 24
    spd = _mixed_spd(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    spd_j = jnp.asarray(spd)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd_j, p)       # noqa
    lock = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-10, max_iter=500)
    seg = pcg_solve_segmented(mv, jnp.asarray(b), diag, tol=1e-10,
                              max_iter=500, segment_size=8)
    assert np.array_equal(np.asarray(lock.iterations),
                          np.asarray(seg.iterations))
    np.testing.assert_allclose(np.asarray(seg.x), np.asarray(lock.x),
                               rtol=1e-6, atol=1e-7)
    assert int(seg.matvec_pairs) == int(lock.matvec_pairs)


def test_mgk_segmented_sparse_gram_tile(tile_batches):
    """Segmented solve over a Gram tile: identical values/iterations to
    lockstep, strictly fewer pair-matvec evaluations; per-pair packs
    path included."""
    (Bi, Bj), g1u, g2u, g1f, g2f = tile_batches
    a1 = row_panel_packs_for_batch(g1u, edge_kernel=EK)
    a2 = row_panel_packs_for_batch(g2u, edge_kernel=EK)
    lock = mgk_pairs_sparse(g1f, g2f, a1, a2, VK, EK, tol=1e-10,
                            gram_tile=(Bi, Bj))
    its = np.asarray(lock.iterations)
    assert its.max() > its.min()     # a genuinely mixed bucket
    seg = mgk_pairs_sparse_segmented(g1f, g2f, a1, a2, VK, EK,
                                     tol=1e-10, segment_size=4,
                                     gram_tile=(Bi, Bj))
    np.testing.assert_allclose(np.asarray(seg.values),
                               np.asarray(lock.values), rtol=1e-6)
    assert np.array_equal(its, np.asarray(seg.iterations))
    assert int(seg.matvec_pairs) < int(lock.matvec_pairs)
    # per-pair packs, same contract
    p1 = row_panel_packs_for_batch(g1f, edge_kernel=EK)
    p2 = row_panel_packs_for_batch(g2f, edge_kernel=EK)
    seg_p = mgk_pairs_sparse_segmented(g1f, g2f, p1, p2, VK, EK,
                                       tol=1e-10, segment_size=4)
    np.testing.assert_allclose(np.asarray(seg_p.values),
                               np.asarray(lock.values), rtol=1e-6)
    assert int(seg_p.matvec_pairs) < int(lock.matvec_pairs)
