"""Belt-and-braces guard for the property-based tests.

``hypothesis`` is a test-only optional dependency (pyproject
``[test]`` extra). When it is installed we re-export the real API; when
it is not, a deterministic mini-shim runs each ``@given`` test over a
small fixed grid of strategy samples instead of erroring at collection
time — the full suite stays collectable (and meaningfully exercised) on
minimal installs.

Only the strategy surface this repo uses is shimmed: ``integers``,
``floats``, ``sampled_from``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback grid
    import inspect
    import itertools

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    def _spread(values, k=3):
        values = list(values)
        if len(values) <= k:
            return values
        return [values[0], values[len(values) // 2], values[-1]]

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(_spread(range(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy([min_value, (min_value + max_value) / 2,
                              max_value])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(_spread(elements))

    st = _Strategies()

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = list(strategies)
        grid = list(itertools.product(
            *(strategies[n].samples for n in names)))[:16]

        def deco(fn):
            def wrapper(*args, **kwargs):
                for combo in grid:
                    fn(*args, **dict(zip(names, combo)), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # hide the strategy-bound params from pytest's fixture
            # resolution; remaining params (e.g. the rng fixture) stay
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in names])
            return wrapper
        return deco
