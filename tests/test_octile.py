"""Octile decomposition: roundtrip, bitmap correctness, counting."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.octile import (count_nonempty_tiles, expand_octiles,
                               octile_decompose, tile_occupancy_histogram)


def _sparse(rng, n, density):
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    e = rng.random((n, n)).astype(np.float32) * (a != 0)
    return a, e


def test_roundtrip(rng):
    a, e = _sparse(rng, 37, 0.1)   # non-multiple-of-8 size
    oset = octile_decompose(a, e)
    a2, e2 = expand_octiles(oset)
    assert np.allclose(a2[:37, :37], a)
    assert np.allclose(e2[:37, :37], e)


def test_bitmap_popcount_equals_nnz(rng):
    a, e = _sparse(rng, 64, 0.07)
    oset = octile_decompose(a, e)
    pop = sum(bin(int(b)).count("1") for b in oset.bitmaps)
    assert pop == oset.nnz == np.count_nonzero(a)


def test_count_matches_decompose(rng):
    a, _ = _sparse(rng, 48, 0.05)
    assert count_nonempty_tiles(a) == octile_decompose(a).n_nonempty


def test_coords_sorted_row_major(rng):
    a, _ = _sparse(rng, 80, 0.04)
    oset = octile_decompose(a)
    c = oset.coords
    keys = c[:, 0] * oset.n_tiles_side + c[:, 1]
    assert (np.diff(keys) > 0).all()     # strictly increasing => no dups


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 60), density=st.floats(0.0, 0.3),
       seed=st.integers(0, 1000))
def test_roundtrip_property(n, density, seed):
    rng = np.random.default_rng(seed)
    a, e = _sparse(rng, n, density)
    oset = octile_decompose(a, e)
    a2, _ = expand_octiles(oset)
    assert np.allclose(a2[:n, :n], a)


def test_histogram_total(rng):
    a, _ = _sparse(rng, 64, 0.1)
    hist = tile_occupancy_histogram(a)
    assert hist.sum() == count_nonempty_tiles(a)
