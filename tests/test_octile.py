"""Octile decomposition: roundtrip, multi-word bitmap correctness,
counting, and the vectorized host-side hot spots."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.octile import (bitmap_popcounts, bitmap_words,
                               count_nonempty_tiles, expand_octiles,
                               octile_decompose, tile_occupancy_histogram)


def _sparse(rng, n, density):
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    e = rng.random((n, n)).astype(np.float32) * (a != 0)
    return a, e


def test_roundtrip(rng):
    a, e = _sparse(rng, 37, 0.1)   # non-multiple-of-8 size
    oset = octile_decompose(a, e)
    a2, e2 = expand_octiles(oset)
    assert np.allclose(a2[:37, :37], a)
    assert np.allclose(e2[:37, :37], e)


def test_roundtrip_padded(rng):
    """expand_octiles must skip the -1 coords that padded() appends."""
    a, e = _sparse(rng, 40, 0.1)
    oset = octile_decompose(a, e).padded(80)
    a2, e2 = expand_octiles(oset)
    assert np.allclose(a2[:40, :40], a)
    assert np.allclose(e2[:40, :40], e)


def test_bitmap_popcount_equals_nnz(rng):
    a, e = _sparse(rng, 64, 0.07)
    oset = octile_decompose(a, e)
    assert oset.bitmaps.shape == (oset.n_nonempty, 1)   # t=8: one word
    assert bitmap_popcounts(oset.bitmaps).sum() == oset.nnz \
        == np.count_nonzero(a)


def test_multiword_bitmap_popcount_equals_nnz(rng):
    """t = 16 and t = 32 tiles need 4 and 16 uint64 words respectively."""
    a, e = _sparse(rng, 96, 0.05)
    for tile in (16, 32):
        oset = octile_decompose(a, e, tile=tile)
        assert bitmap_words(tile) == -(-(tile * tile) // 64)
        assert oset.bitmaps.shape == (oset.n_nonempty, bitmap_words(tile))
        assert bitmap_popcounts(oset.bitmaps).sum() == oset.nnz \
            == np.count_nonzero(a)
        assert 0.0 < oset.density <= 1.0


def test_bitmap_bit_positions(rng):
    """Bit q = i*t + j of word q // 64 maps exactly to element (i, j)."""
    for tile in (8, 16):
        a = np.zeros((tile, tile), np.float32)
        hits = [(0, 0), (1, 2), (tile - 1, tile - 1)]
        for i, j in hits:
            a[i, j] = 1.0
        oset = octile_decompose(a, tile=tile)
        assert oset.n_nonempty == 1
        words = oset.bitmaps[0]
        for i, j in hits:
            q = i * tile + j
            assert (int(words[q // 64]) >> (q % 64)) & 1


def test_count_matches_decompose(rng):
    a, _ = _sparse(rng, 48, 0.05)
    assert count_nonempty_tiles(a) == octile_decompose(a).n_nonempty


def test_coords_sorted_row_major(rng):
    a, _ = _sparse(rng, 80, 0.04)
    oset = octile_decompose(a)
    c = oset.coords
    keys = c[:, 0] * oset.n_tiles_side + c[:, 1]
    assert (np.diff(keys) > 0).all()     # strictly increasing => no dups


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 60), density=st.floats(0.0, 0.3),
       seed=st.integers(0, 1000))
def test_roundtrip_property(n, density, seed):
    rng = np.random.default_rng(seed)
    a, e = _sparse(rng, n, density)
    oset = octile_decompose(a, e)
    a2, _ = expand_octiles(oset)
    assert np.allclose(a2[:n, :n], a)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 72), density=st.floats(0.0, 0.25),
       tile=st.sampled_from([16, 32]), seed=st.integers(0, 1000))
def test_multiword_roundtrip_property(n, density, tile, seed):
    """Multi-word bitmaps round-trip: octile_decompose -> expand_octiles
    reconstructs the matrix and popcounts stay consistent for t > 8."""
    rng = np.random.default_rng(seed)
    a, e = _sparse(rng, n, density)
    oset = octile_decompose(a, e, tile=tile)
    a2, e2 = expand_octiles(oset)
    assert np.allclose(a2[:n, :n], a)
    assert np.allclose(e2[:n, :n], e)
    assert bitmap_popcounts(oset.bitmaps).sum() == np.count_nonzero(a)


def test_histogram_total(rng):
    a, _ = _sparse(rng, 64, 0.1)
    hist = tile_occupancy_histogram(a)
    assert hist.sum() == count_nonempty_tiles(a)
