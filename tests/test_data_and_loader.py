"""Data pipeline: generators + bucketing loader."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.graph import Graph, batch_from_graphs
from repro.data import (bucket_graphs, make_drugbank_like_dataset,
                        make_pdb_like_dataset, make_synthetic_dataset)


def test_nws_structure(rng):
    gs = make_synthetic_dataset("nws", n_graphs=4, n_nodes=96, seed=0)
    for g in gs:
        a = g.adjacency
        assert np.allclose(a, a.T)
        assert np.all(np.diag(a) == 0)
        deg = (a != 0).sum(1)
        assert deg.mean() >= 5.5          # ring degree 6 + shortcuts


def test_ba_scale_free_hubs(rng):
    gs = make_synthetic_dataset("ba", n_graphs=4, n_nodes=96, seed=0)
    for g in gs:
        deg = (g.adjacency != 0).sum(1)
        assert deg.max() > 3 * np.median(deg)   # hubs exist


def test_pdb_like_spatial_locality():
    gs, coords = make_pdb_like_dataset(n_graphs=3, seed=1)
    for g, c in zip(gs, coords):
        i, j = np.nonzero(g.adjacency)
        d = np.linalg.norm(c[i] - c[j], axis=1)
        assert d.max() < 1.8 + 1e-5       # edges respect the cutoff
        assert np.allclose(g.edge_labels, g.edge_labels.T)


def test_drugbank_like_size_tail():
    gs = make_drugbank_like_dataset(n_graphs=200, seed=0)
    sizes = np.array([g.n_nodes for g in gs])
    assert sizes.min() >= 2
    assert sizes.max() > 100              # long tail (paper: 1..551)
    assert np.median(sizes) < 60


def test_padding_is_inert(rng):
    gs = make_synthetic_dataset("nws", n_graphs=2, n_nodes=10, seed=0)
    b16 = batch_from_graphs(gs, pad_to=16)
    b32 = batch_from_graphs(gs, pad_to=32)
    from repro.core import KroneckerDelta, SquareExponential, mgk_pairs
    r16 = mgk_pairs(b16, b16, KroneckerDelta(0.5), SquareExponential(1.0),
                    tol=1e-12)
    r32 = mgk_pairs(b32, b32, KroneckerDelta(0.5), SquareExponential(1.0),
                    tol=1e-12)
    np.testing.assert_allclose(np.asarray(r16.values),
                               np.asarray(r32.values), rtol=1e-4)


def test_buckets_partition_dataset():
    gs = make_drugbank_like_dataset(n_graphs=60, seed=2)
    ds = bucket_graphs(gs, max_buckets=5)
    all_idx = sorted(i for b in ds.buckets for i in b.indices)
    assert all_idx == list(range(60))
    for b in ds.buckets:
        for i in b.indices:
            assert gs[i].n_nodes <= b.pad_to


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_graph_create_rejects_asymmetric(seed):
    rng = np.random.default_rng(seed)
    a = rng.random((5, 5)).astype(np.float32)
    a[0, 1], a[1, 0] = 1.0, 0.5
    try:
        Graph.create(a)
        assert False, "should have raised"
    except ValueError:
        pass
