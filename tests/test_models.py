"""Per-architecture smoke tests (tasking requirement): reduced config,
one forward + train step on CPU, output shapes + no NaNs; decode
consistency with the full forward."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.model import (decode_step, forward, init_cache,
                                init_params, segments_of)
from repro.train.steps import make_train_step

ARCH_IDS = list(ARCHS)


def _batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.d_model)),
            cfg.dtype)
    if cfg.family == "audio":
        batch["audio_frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train(arch, rng):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, rng)
    logits, _, aux = forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), arch
    opt_init, step = make_train_step(cfg, lr=1e-3)
    opt = opt_init(params)
    jit_step = jax.jit(step)
    p, opt, m0 = jit_step(params, opt, batch)
    p, opt, m1 = jit_step(p, opt, batch)
    p, opt, m2 = jit_step(p, opt, batch)
    assert np.isfinite(float(m2["loss"])), arch
    assert float(m2["loss"]) < float(m0["loss"]), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_consistency(arch, rng):
    cfg = ARCHS[arch].reduced()
    params = init_params(cfg, jax.random.key(1))
    batch = _batch(cfg, rng, B=1, S=12)
    tokens = batch["tokens"]
    full, _, _ = forward(cfg, params, batch)
    cache = init_cache(cfg, 1, 24)
    pre = dict(batch)
    pre["tokens"] = tokens[:, :6]
    lp, cache, _ = forward(cfg, params, pre, cache=cache)
    outs = [lp[:, -1]]
    for t in range(6, 12):
        dl, cache = decode_step(cfg, params, cache, tokens[:, t:t + 1])
        outs.append(dl[:, 0])
    inc = jnp.stack(outs, axis=1)
    denom = float(jnp.max(jnp.abs(full[:, 5:11]))) + 1e-9
    err = float(jnp.max(jnp.abs(inc[:, :-1] - full[:, 5:11]))) / denom
    assert err < 5e-3, (arch, err)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_segments_cover_all_layers(arch):
    cfg = ARCHS[arch]
    segs = segments_of(cfg)
    total = sum(n * len(pat) for n, pat in segs)
    assert total == cfg.n_layers, (arch, total)


def test_full_configs_match_spec():
    """The exact published numbers from the tasking table."""
    c = ARCHS["phi4-mini-3.8b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 24, 8, 8192, 200064)
    c = ARCHS["qwen3-14b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == \
        (40, 5120, 40, 17408, 151936) and c.qk_norm
    c = ARCHS["deepseek-v3-671b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == \
        (61, 7168, 128, 129280)
    assert c.moe.n_experts == 256 and c.moe.top_k == 8 and \
        c.moe.n_shared == 1 and c.mla is not None and c.mtp_heads == 1
    c = ARCHS["qwen3-moe-235b-a22b"]
    assert c.n_layers == 94 and c.moe.n_experts == 128 and c.moe.top_k == 8
    c = ARCHS["gemma3-12b"]
    assert c.local_global_ratio == 5 and c.vocab_size == 262144
    c = ARCHS["mamba2-2.7b"]
    assert c.n_layers == 64 and c.d_model == 2560 and \
        c.ssm.d_state == 128 and c.d_ff == 0
    c = ARCHS["jamba-1.5-large-398b"]
    assert c.attn_every == 8 and c.moe.n_experts == 16 and c.moe.top_k == 2
    c = ARCHS["whisper-large-v3"]
    assert c.encoder_layers == 32 and c.n_layers == 32 and \
        c.vocab_size == 51866
    c = ARCHS["llama-3.2-vision-90b"]
    assert c.n_layers == 100 and c.cross_attn_every == 5
    c = ARCHS["qwen3-0.6b"]
    assert c.n_layers == 28 and c.d_model == 1024


def test_param_counts_plausible():
    """n_params() should land near the advertised sizes."""
    expect = {
        "phi4-mini-3.8b": (3.0e9, 5.0e9),
        "qwen3-14b": (12e9, 17e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "gemma3-12b": (10e9, 14e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "llama-3.2-vision-90b": (80e9, 100e9),
        "mamba2-2.7b": (2.0e9, 3.4e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
    }
    for arch, (lo, hi) in expect.items():
        n = ARCHS[arch].n_params()
        assert lo <= n <= hi, (arch, n)
