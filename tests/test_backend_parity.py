"""Cross-backend parity: every XMV backend — dense Pallas, block-sparse
(legacy per-pair loop AND batched grid), elementwise, lowrank — must apply
the same operator on random masked batches; classic and pipelined PCG must
produce the same iterates; and the batched block-sparse bucket matvec must
be exactly ONE pallas_call (the tentpole claim of PR 1, checked on the
jaxpr)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.base_kernels import KroneckerDelta, SquareExponential
from repro.core.graph import batch_from_graphs
from repro.core.mgk import build_product_system, mgk_pairs, mgk_pairs_sparse
from repro.core.pcg import pcg_solve
from repro.core.xmv import xmv_elementwise, xmv_full, xmv_lowrank
from repro.data import make_drugbank_like_dataset
from repro.kernels.ops import packs_for_batch, xmv_block_sparse_unrolled
from repro.kernels.xmv_block_sparse import xmv_block_sparse_batched
from repro.kernels.xmv_dense import xmv_dense_batched

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)
TOL = dict(rtol=1e-5, atol=1e-5)


@pytest.fixture(scope="module")
def masked_batch():
    """Two aligned batches of real-ish sparse graphs + their tile packs."""
    gs = make_drugbank_like_dataset(16, seed=11)
    gs = [g for g in gs if 6 <= g.n_nodes <= 48][:8]
    assert len(gs) == 8
    g1 = batch_from_graphs(gs[:4], pad_to=48)
    g2 = batch_from_graphs(gs[4:], pad_to=48)
    return g1, g2, packs_for_batch(g1), packs_for_batch(g2)


def _random_p(g1, g2, seed=0):
    rng = np.random.default_rng(seed)
    B, n = g1.adjacency.shape[:2]
    m = g2.adjacency.shape[1]
    return jnp.asarray(rng.random((B, n, m)).astype(np.float32))


def test_all_backends_agree(masked_batch):
    """dense pallas / block-sparse (old loop + new batched grid) /
    elementwise / lowrank vs the full-materialization oracle."""
    g1, g2, p1, p2 = masked_batch
    P = _random_p(g1, g2)
    args = (g1.adjacency, g1.edge_labels, g2.adjacency, g2.edge_labels, P)

    y_full = jax.vmap(
        lambda a, e, ap, ep, p: xmv_full(a, e, ap, ep, p, EK))(*args)
    y_elem = jax.vmap(
        lambda a, e, ap, ep, p: xmv_elementwise(a, e, ap, ep, p, EK))(*args)
    y_lr = jax.vmap(
        lambda a, e, ap, ep, p: xmv_lowrank(a, e, ap, ep, p, EK))(*args)
    y_dense = xmv_dense_batched(*args, EK)
    y_sp_old = xmv_block_sparse_unrolled(p1, p2, P, EK)
    y_sp_new = xmv_block_sparse_batched(p1, p2, P, EK)

    ref = np.asarray(y_full)
    for name, y in [("elementwise", y_elem), ("lowrank", y_lr),
                    ("pallas_dense", y_dense), ("sparse_unrolled", y_sp_old),
                    ("sparse_batched", y_sp_new)]:
        np.testing.assert_allclose(np.asarray(y), ref, err_msg=name, **TOL)


def test_elementwise_non_divisible_chunk(masked_batch):
    """chunk is clamped, not an error, when it doesn't divide n."""
    g1, g2, _, _ = masked_batch
    P = _random_p(g1, g2)
    a, e = g1.adjacency[0], g1.edge_labels[0]
    ap, ep = g2.adjacency[0], g2.edge_labels[0]
    y_ref = xmv_full(a, e, ap, ep, P[0], EK)
    y = xmv_elementwise(a, e, ap, ep, P[0], EK, chunk=7)  # 7 ∤ 48
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)


def _count_primitive(jaxpr, name):
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                count += _count_primitive(v.jaxpr, name)
            elif isinstance(v, jax.extend.core.Jaxpr):
                count += _count_primitive(v, name)
    return count


def test_batched_sparse_is_single_launch(masked_batch):
    """The tentpole: one bucket matvec == ONE pallas_call, however many
    pairs are in the bucket (vs B calls in the legacy loop)."""
    g1, g2, p1, p2 = masked_batch
    P = _random_p(g1, g2)

    def batched(P):
        return xmv_block_sparse_batched(p1, p2, P, EK)

    def unrolled(P):
        return xmv_block_sparse_unrolled(p1, p2, P, EK)

    B = P.shape[0]
    assert B >= 4
    n_batched = _count_primitive(jax.make_jaxpr(batched)(P).jaxpr,
                                 "pallas_call")
    n_unrolled = _count_primitive(jax.make_jaxpr(unrolled)(P).jaxpr,
                                  "pallas_call")
    assert n_batched == 1, f"expected 1 pallas_call, traced {n_batched}"
    assert n_unrolled == B


def test_fused_epilogue_matches_unfused(masked_batch):
    """In-kernel diag*p - y must be bitwise-close to the two-step
    reference on both the dense and block-sparse paths."""
    g1, g2, p1, p2 = masked_batch
    P = _random_p(g1, g2)
    rng = np.random.default_rng(1)
    diag = jnp.asarray(
        rng.random(P.shape).astype(np.float32) + 1.0)

    y_sp = xmv_block_sparse_batched(p1, p2, P, EK)
    ref_sp = np.asarray(diag) * np.asarray(P) - np.asarray(y_sp)
    fused_sp = xmv_block_sparse_batched(p1, p2, P, EK, diag=diag)
    np.testing.assert_allclose(np.asarray(fused_sp), ref_sp, **TOL)

    args = (g1.adjacency, g1.edge_labels, g2.adjacency, g2.edge_labels, P)
    y_d = xmv_dense_batched(*args, EK)
    ref_d = np.asarray(diag) * np.asarray(P) - np.asarray(y_d)
    fused_d = xmv_dense_batched(*args, EK, diag=diag)
    np.testing.assert_allclose(np.asarray(fused_d), ref_d, **TOL)


def test_pipelined_pcg_matches_classic_iterates(rng):
    B, N = 4, 32
    a = rng.random((B, N, N)).astype(np.float32)
    spd = np.einsum("bij,bkj->bik", a, a) + \
        N * np.eye(N, dtype=np.float32)[None]
    b = rng.random((B, N)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    rc = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-9, max_iter=500)
    rp = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-9, max_iter=500,
                   variant="pipelined")
    assert bool(rc.converged.all()) and bool(rp.converged.all())
    # same convergence trajectory: iteration counts within +-1
    assert int(np.abs(np.asarray(rc.iterations)
                      - np.asarray(rp.iterations)).max()) <= 1
    np.testing.assert_allclose(np.asarray(rc.x), np.asarray(rp.x),
                               rtol=1e-3, atol=1e-5)

    # fixed-iteration contract: both run the exact same trip count
    fc = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-12, fixed_iters=20)
    fp = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-12, fixed_iters=20,
                   variant="pipelined")
    np.testing.assert_allclose(np.asarray(fc.x), np.asarray(fp.x),
                               rtol=1e-3, atol=1e-5)


def test_mgk_pipelined_matches_classic(masked_batch):
    g1, g2, p1, p2 = masked_batch
    rc = mgk_pairs(g1, g2, VK, EK, method="pallas", tol=1e-10)
    rp = mgk_pairs(g1, g2, VK, EK, method="pallas", tol=1e-10,
                   pcg_variant="pipelined")
    np.testing.assert_allclose(np.asarray(rc.values), np.asarray(rp.values),
                               rtol=1e-5)
    assert int(np.abs(np.asarray(rc.iterations)
                      - np.asarray(rp.iterations)).max()) <= 1

    rs_c = mgk_pairs_sparse(g1, g2, p1, p2, VK, EK, tol=1e-10)
    rs_p = mgk_pairs_sparse(g1, g2, p1, p2, VK, EK, tol=1e-10,
                            pcg_variant="pipelined")
    np.testing.assert_allclose(np.asarray(rs_c.values),
                               np.asarray(rs_p.values), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(rs_c.values),
                               np.asarray(rc.values), rtol=1e-4)


def test_mgk_sparse_fixed_iters_plumbed(masked_batch):
    """fixed_iters used to be silently ignored by mgk_pairs_sparse."""
    g1, g2, p1, p2 = masked_batch
    free = mgk_pairs_sparse(g1, g2, p1, p2, VK, EK, tol=1e-10)
    k = int(np.asarray(free.iterations).max())
    fixed = mgk_pairs_sparse(g1, g2, p1, p2, VK, EK, tol=1e-10,
                             fixed_iters=k)
    np.testing.assert_allclose(np.asarray(fixed.values),
                               np.asarray(free.values), rtol=1e-6)
    # a truncated run must actually truncate (proves the plumbing)
    short = mgk_pairs_sparse(g1, g2, p1, p2, VK, EK, tol=1e-30,
                             fixed_iters=3)
    assert int(np.asarray(short.iterations).max()) == 3


def test_fused_is_default_cg_operator(masked_batch):
    """The CG hot path for method='pallas' and the sparse path must carry
    the diagonal term in-kernel: the traced solve contains NO standalone
    diag*p multiply-subtract on the [B, n*m] vector outside the kernel.
    Cheap proxy: the matvec jaxpr's only computation at product-vector
    width is the pallas_call itself."""
    g1, g2, p1, p2 = masked_batch
    sys_ = build_product_system(g1, g2, VK)
    from repro.core.mgk import _make_matvec
    mv = _make_matvec(g1, g2, sys_, EK, "pallas", 8)
    B = g1.adjacency.shape[0]
    nm = g1.adjacency.shape[1] * g2.adjacency.shape[1]
    p = jnp.ones((B, nm), jnp.float32)
    jaxpr = jax.make_jaxpr(mv)(p).jaxpr
    assert _count_primitive(jaxpr, "pallas_call") >= 1
    # no elementwise sub at [B, n*m] outside the kernel
    def _outer_subs(jx):
        subs = 0
        for eqn in jx.eqns:
            if eqn.primitive.name == "sub" and \
                    tuple(eqn.outvars[0].aval.shape) == (B, nm):
                subs += 1
            for v in eqn.params.values():
                if isinstance(v, jax.extend.core.ClosedJaxpr) and \
                        eqn.primitive.name != "pallas_call":
                    subs += _outer_subs(v.jaxpr)
        return subs
    assert _outer_subs(jaxpr) == 0
