"""Flash / chunked attention vs the einsum oracle: shape x dtype x
GQA x masking sweeps (per-kernel allclose requirement)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.ops import attention, attention_chunked
from repro.kernels.ref import attention_ref


def _qkv(rng, B, Hq, Hkv, S, D, dtype=np.float32):
    q = rng.standard_normal((B, Hq, S, D)).astype(dtype)
    k = rng.standard_normal((B, Hkv, S, D)).astype(dtype)
    v = rng.standard_normal((B, Hkv, S, D)).astype(dtype)
    return q, k, v


def _oracle(q, k, v, **kw):
    Hq, Hkv = q.shape[1], k.shape[1]
    if Hq != Hkv:
        k = np.repeat(k, Hq // Hkv, axis=1)
        v = np.repeat(v, Hq // Hkv, axis=1)
    return np.asarray(attention_ref(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), **kw))


@pytest.mark.parametrize("impl", ["pallas", "chunked", "reference"])
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 4, 128, 64), (2, 8, 2, 128, 32), (1, 4, 1, 256, 128),
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_attention_sweep(impl, B, Hq, Hkv, S, D, causal, window, rng):
    q, k, v = _qkv(rng, B, Hq, Hkv, S, D)
    out = attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                    impl=impl, causal=causal, window=window)
    ref = _oracle(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "chunked"])
def test_attention_bf16(impl, rng):
    q, k, v = _qkv(rng, 1, 4, 2, 128, 64)
    qb = jnp.asarray(q, jnp.bfloat16)
    kb = jnp.asarray(k, jnp.bfloat16)
    vb = jnp.asarray(v, jnp.bfloat16)
    out = attention(qb, kb, vb, impl=impl, causal=True)
    ref = _oracle(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               rtol=0.06, atol=0.06)


def test_chunked_block_sizes(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 256, 32)
    ref = _oracle(q, k, v, causal=True)
    for bq, bk in [(64, 128), (256, 64), (32, 32)]:
        out = attention_chunked(jnp.asarray(q), jnp.asarray(k),
                                jnp.asarray(v), causal=True,
                                blk_q=bq, blk_k=bk)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4,
                                   atol=2e-5)
