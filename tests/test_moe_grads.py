"""MoE gather-only custom VJPs vs a dense all-experts reference.

The production layer never materializes scatters (forward or backward);
this test proves the hand-written transposes are exact."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.moe import moe_init, moe_layer

CFG = MoEConfig(n_experts=4, top_k=2, d_expert=16, n_shared=0,
                capacity_factor=2.0)   # dropless
D = 32


def _ref_layer(p, x):
    logits = jnp.einsum("bsd,de->bse", x, p.router)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, CFG.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    gate_full = jnp.zeros_like(probs)
    for slot in range(CFG.top_k):
        gate_full = gate_full + jax.nn.one_hot(
            gi[..., slot], CFG.n_experts) * gv[..., slot:slot + 1]
    g = jnp.einsum("bsd,edf->bsef", x, p.w_gate)
    u = jnp.einsum("bsd,edf->bsef", x, p.w_up)
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("bsef,efd->bsed", h, p.w_down)
    return jnp.einsum("bsed,bse->bsd", eo, gate_full)


def test_forward_matches_dense_reference(rng):
    p = moe_init(jax.random.key(0), D, CFG, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, D)), jnp.float32)
    out, _ = moe_layer(p, x, CFG)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_ref_layer(p, x)),
                               atol=1e-5)


def test_custom_vjp_gradients_exact(rng):
    p = moe_init(jax.random.key(0), D, CFG, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, D)), jnp.float32)

    loss_ours = lambda p, x: jnp.sum(moe_layer(p, x, CFG)[0] ** 2)  # noqa
    loss_ref = lambda p, x: jnp.sum(_ref_layer(p, x) ** 2)          # noqa
    gx = jax.grad(loss_ours, argnums=1)(p, x)
    gx_ref = jax.grad(loss_ref, argnums=1)(p, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               atol=1e-4)
    gp = jax.grad(loss_ours)(p, x)
    gp_ref = jax.grad(loss_ref)(p, x)
    for f in ("w_gate", "w_up", "w_down", "router"):
        a, b = np.asarray(getattr(gp, f)), np.asarray(getattr(gp_ref, f))
        scale = np.abs(b).max() + 1e-9
        assert np.abs(a - b).max() / scale < 1e-4, f
