"""Gram-matrix contract: on a small bucketed dataset the GramDriver
output must be symmetric, match pairwise ``mgk_direct``, and be PSD
after standard jitter; its gradient blocks (run_with_grad) must match
central finite differences of the Gram entries, dense and sparse paths
agreeing with each other."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import KroneckerDelta, SquareExponential
from repro.core.reference import mgk_direct
from repro.data import bucket_graphs, make_drugbank_like_dataset
from repro.distributed import GramDriver

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)


@pytest.fixture(scope="module")
def setup():
    graphs = [g for g in make_drugbank_like_dataset(16, seed=1)
              if 5 <= g.n_nodes <= 40][:8]
    assert len(graphs) == 8
    ds = bucket_graphs(graphs, max_buckets=2)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    return graphs, ds, mesh


def _driver(ds, mesh, **kw):
    base = dict(vertex_kernel=VK, edge_kernel=EK, method="lowrank",
                pairs_per_block=16, normalize=False, tol=1e-10)
    base.update(kw)
    return GramDriver(ds, mesh, **base)


@pytest.fixture(scope="module")
def gram_and_grads(setup):
    _, ds, mesh = setup
    return _driver(ds, mesh).run_with_grad()


def test_gram_symmetric_and_matches_direct(setup, gram_and_grads):
    graphs, _, _ = setup
    K, _ = gram_and_grads
    assert K.shape == (len(graphs), len(graphs))
    assert not np.isnan(K).any()
    np.testing.assert_allclose(K, K.T, rtol=1e-5)
    for i, j in [(0, 0), (0, 3), (2, 5), (6, 7)]:
        ref = mgk_direct(graphs[i], graphs[j], VK, EK)
        assert K[i, j] == pytest.approx(ref, rel=2e-3)


def test_gram_psd_after_jitter(gram_and_grads):
    K, _ = gram_and_grads
    jitter = 1e-8 * np.trace(K) / K.shape[0]
    w = np.linalg.eigvalsh(K + jitter * np.eye(K.shape[0]))
    assert w.min() > -1e-6 * abs(w.max())


def test_grad_blocks_match_finite_differences(setup, gram_and_grads):
    _, ds, mesh = setup
    K, G = gram_and_grads
    assert set(G) == {"vertex.h", "edge.alpha"}
    for g in G.values():
        np.testing.assert_allclose(g, g.T, rtol=1e-4, atol=1e-8)
    h = 2e-3
    cases = [
        ("edge.alpha",
         lambda s: _driver(ds, mesh,
                           edge_kernel=SquareExponential(1.0 + s,
                                                         rank=12))),
        ("vertex.h",
         lambda s: _driver(ds, mesh,
                           vertex_kernel=KroneckerDelta(0.5 + s,
                                                        n_labels=8))),
    ]
    for key, make in cases:
        Kp = make(+h).run()
        Km = make(-h).run()
        fd = (Kp - Km) / (2 * h)
        np.testing.assert_allclose(G[key], fd, rtol=2e-3, atol=2e-5)


def test_sparse_grad_blocks_match_dense(setup, gram_and_grads):
    """The pack-cached sparse gradient path (values_w/values_grad baked
    once per graph, trust_pack_weights) must reproduce the dense-path
    gradient Gram."""
    _, ds, mesh = setup
    K, G = gram_and_grads
    Ks, Gs = _driver(ds, mesh, method="pallas_sparse").run_with_grad()
    np.testing.assert_allclose(Ks, K, rtol=2e-3, atol=1e-7)
    for key in G:
        np.testing.assert_allclose(Gs[key], G[key], rtol=5e-3, atol=2e-5)


def test_grad_blocks_survive_the_chunk_store(setup, tmp_path_factory):
    """Gradient blocks ride the fault-tolerance path too: persisted per
    block, reassembled identically on restart."""
    from repro.distributed.checkpoint import ChunkStore
    _, ds, mesh = setup
    root = str(tmp_path_factory.mktemp("gram_grad_store"))
    drv = _driver(ds, mesh, store=ChunkStore(root))
    K1, G1 = drv.run_with_grad()
    # a fresh driver over the same store recomputes nothing
    drv2 = _driver(ds, mesh, store=ChunkStore(root))
    K2, G2 = drv2.run_with_grad()
    np.testing.assert_array_equal(K1, K2)
    for key in G1:
        np.testing.assert_array_equal(G1[key], G2[key])
