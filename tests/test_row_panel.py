"""Row-panel block-sparse XMV: parity with the dense oracle across tile
sizes and modes (elementwise VPU vs MXU low-rank contraction), ragged
slot counts (including tile rows with ZERO real octiles), the fused
diagonal epilogue, single-launch jaxpr shape, and the mgk dispatch."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.base_kernels import CompactPolynomial, KroneckerDelta, \
    SquareExponential
from repro.core.graph import batch_from_graphs
from repro.core.mgk import mgk_pairs, mgk_pairs_sparse
from repro.core.xmv import xmv_full
from repro.data import make_drugbank_like_dataset
from repro.kernels.ops import row_panel_packs_for_batch, \
    stack_row_panel_packs
from repro.kernels.xmv_block_sparse import pack_graph_row_panels, \
    xmv_row_panel, xmv_row_panel_batched

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)
TOL = dict(rtol=1e-5, atol=1e-5)


def _sparse_pair(rng, n, density=0.06, dead_band=None):
    """Random symmetric sparse graph; ``dead_band=(lo, hi)`` zeroes node
    rows/cols [lo, hi) so whole tile rows carry zero octiles."""
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    if dead_band is not None:
        lo, hi = dead_band
        a[lo:hi, :] = 0.0
        a[:, lo:hi] = 0.0
    e = rng.random((n, n)).astype(np.float32) * (a != 0)
    return a, e


def _oracle(a, e, ap, ep, P):
    return np.asarray(xmv_full(jnp.asarray(a), jnp.asarray(e),
                               jnp.asarray(ap), jnp.asarray(ep),
                               jnp.asarray(P), EK))


@pytest.mark.parametrize("tile", [8, 16, 32])
def test_row_panel_matches_oracle_all_tiles(rng, tile):
    """Elementwise AND MXU modes vs the full-materialization oracle for
    every supported octile edge (the acceptance parity sweep)."""
    n = 64
    a, e = _sparse_pair(rng, n)
    ap, ep = _sparse_pair(rng, n)
    P = rng.random((n, n)).astype(np.float32)
    ref = _oracle(a, e, ap, ep, P)
    p1 = pack_graph_row_panels(a, e, tile=tile, edge_kernel=EK)
    p2 = pack_graph_row_panels(ap, ep, tile=tile, edge_kernel=EK)
    y_elem = xmv_row_panel(p1, p2, jnp.asarray(P), EK, mode="elementwise")
    y_mxu = xmv_row_panel(p1, p2, jnp.asarray(P), EK, mode="mxu")
    np.testing.assert_allclose(np.asarray(y_elem), ref,
                               err_msg=f"elementwise t={tile}", **TOL)
    np.testing.assert_allclose(np.asarray(y_mxu), ref,
                               err_msg=f"mxu t={tile}", **TOL)
    # acceptance: the two modes agree to 1e-5 relative error
    np.testing.assert_allclose(np.asarray(y_mxu), np.asarray(y_elem),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("tile", [8, 16])
def test_row_panel_ragged_and_empty_rows(rng, tile):
    """Rows with zero real octiles (count = 0) and strongly ragged slot
    counts must still be exact — the SMEM count predicates the in-kernel
    reduction."""
    n = 64
    # kill two whole tile-row bands on graph 1, one on graph 2
    a, e = _sparse_pair(rng, n, density=0.15,
                        dead_band=(tile, 2 * tile))
    a[3 * tile:4 * tile, :] = 0.0
    a[:, 3 * tile:4 * tile] = 0.0
    e = e * (a != 0)
    ap, ep = _sparse_pair(rng, n, density=0.03, dead_band=(0, tile))
    P = rng.random((n, n)).astype(np.float32)
    ref = _oracle(a, e, ap, ep, P)
    p1 = pack_graph_row_panels(a, e, tile=tile, edge_kernel=EK)
    p2 = pack_graph_row_panels(ap, ep, tile=tile, edge_kernel=EK)
    assert int(np.asarray(p1.count).min()) == 0     # truly empty rows
    for mode in ("elementwise", "mxu"):
        y = xmv_row_panel(p1, p2, jnp.asarray(P), EK, mode=mode)
        np.testing.assert_allclose(np.asarray(y), ref, err_msg=mode, **TOL)


def test_row_panel_elementwise_only_kernel(rng):
    """Edge kernels without a feature expansion run the VPU mode; packs
    built without one carry values_w=None and 'auto' resolves to it."""
    ck = CompactPolynomial(1.0)
    n = 40
    a, e = _sparse_pair(rng, n, density=0.1)
    ap, ep = _sparse_pair(rng, n, density=0.1)
    P = rng.random((n, n)).astype(np.float32)
    p1 = pack_graph_row_panels(a, e, edge_kernel=ck)   # no expansion
    p2 = pack_graph_row_panels(ap, ep, edge_kernel=ck)
    assert p1.values_w is None
    ref = np.asarray(xmv_full(jnp.asarray(a), jnp.asarray(e),
                              jnp.asarray(ap), jnp.asarray(ep),
                              jnp.asarray(P), ck))
    y = xmv_row_panel(p1, p2, jnp.asarray(P), ck)      # mode="auto"
    np.testing.assert_allclose(np.asarray(y), ref, **TOL)
    with pytest.raises(ValueError, match="mxu"):
        xmv_row_panel(p1, p2, jnp.asarray(P), ck, mode="mxu")


@pytest.fixture(scope="module")
def masked_batch():
    gs = make_drugbank_like_dataset(16, seed=11)
    gs = [g for g in gs if 6 <= g.n_nodes <= 48][:8]
    assert len(gs) == 8
    g1 = batch_from_graphs(gs[:4], pad_to=48)
    g2 = batch_from_graphs(gs[4:], pad_to=48)
    return g1, g2


def _random_p(g1, g2, seed=0):
    rng = np.random.default_rng(seed)
    B, n = g1.adjacency.shape[:2]
    m = g2.adjacency.shape[1]
    return jnp.asarray(rng.random((B, n, m)).astype(np.float32))


def test_batched_row_panel_matches_oracle(masked_batch):
    g1, g2 = masked_batch
    P = _random_p(g1, g2)
    args = (g1.adjacency, g1.edge_labels, g2.adjacency, g2.edge_labels, P)
    ref = np.asarray(jax.vmap(
        lambda a, e, ap, ep, p: xmv_full(a, e, ap, ep, p, EK))(*args))
    r1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
    r2 = row_panel_packs_for_batch(g2, edge_kernel=EK)
    for mode in ("elementwise", "mxu"):
        y = xmv_row_panel_batched(r1, r2, P, EK, mode=mode)
        np.testing.assert_allclose(np.asarray(y), ref, err_msg=mode, **TOL)


def test_batched_row_panel_fused_epilogue(masked_batch):
    g1, g2 = masked_batch
    P = _random_p(g1, g2)
    rng = np.random.default_rng(1)
    diag = jnp.asarray(rng.random(P.shape).astype(np.float32) + 1.0)
    r1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
    r2 = row_panel_packs_for_batch(g2, edge_kernel=EK)
    for mode in ("elementwise", "mxu"):
        y = xmv_row_panel_batched(r1, r2, P, EK, mode=mode)
        ref = np.asarray(diag) * np.asarray(P) - np.asarray(y)
        fused = xmv_row_panel_batched(r1, r2, P, EK, diag=diag, mode=mode)
        np.testing.assert_allclose(np.asarray(fused), ref, err_msg=mode,
                                   **TOL)


def _count_primitive(jaxpr, name):
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            if isinstance(v, jax.extend.core.ClosedJaxpr):
                count += _count_primitive(v.jaxpr, name)
            elif isinstance(v, jax.extend.core.Jaxpr):
                count += _count_primitive(v, name)
    return count


def test_row_panel_is_single_launch(masked_batch):
    """The row-panel bucket matvec must still be exactly ONE pallas_call
    per matvec — the in-kernel slot reduction must not re-introduce
    per-slot (or per-pair) launches."""
    g1, g2 = masked_batch
    P = _random_p(g1, g2)
    r1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
    r2 = row_panel_packs_for_batch(g2, edge_kernel=EK)
    for mode in ("elementwise", "mxu"):
        n_calls = _count_primitive(
            jax.make_jaxpr(
                lambda P: xmv_row_panel_batched(r1, r2, P, EK, mode=mode)
            )(P).jaxpr, "pallas_call")
        assert n_calls == 1, f"{mode}: traced {n_calls} pallas_calls"


def test_mgk_sparse_row_panel_modes_agree(masked_batch):
    """mgk_pairs_sparse over row-panel packs (both modes) vs the dense
    reference solve."""
    g1, g2 = masked_batch
    ref = mgk_pairs(g1, g2, VK, EK, method="full", tol=1e-10)
    r1e = row_panel_packs_for_batch(g1)
    r2e = row_panel_packs_for_batch(g2)
    r1w = row_panel_packs_for_batch(g1, edge_kernel=EK)
    r2w = row_panel_packs_for_batch(g2, edge_kernel=EK)
    res_e = mgk_pairs_sparse(g1, g2, r1e, r2e, VK, EK,
                             sparse_mode="elementwise", tol=1e-10)
    res_m = mgk_pairs_sparse(g1, g2, r1w, r2w, VK, EK, sparse_mode="mxu",
                             tol=1e-10)
    np.testing.assert_allclose(np.asarray(res_e.values),
                               np.asarray(ref.values), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(res_m.values),
                               np.asarray(ref.values), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(res_m.values),
                               np.asarray(res_e.values), rtol=1e-5)


def test_stack_row_panel_packs_rejects_mixed(rng):
    a, e = _sparse_pair(rng, 16, density=0.2)
    with_w = pack_graph_row_panels(a, e, edge_kernel=EK)
    without = pack_graph_row_panels(a, e)
    with pytest.raises(ValueError, match="mixing"):
        stack_row_panel_packs([with_w, without])


# -- bf16 pack streaming (DESIGN.md §9.4) ----------------------------------
#
# pack_dtype=jnp.bfloat16 halves the HBM bytes every matvec streams;
# the kernels upcast operands in VMEM and accumulate in f32, so the
# only precision cost is ONE rounding of the stored values — parity
# against the f32-pack result holds at bf16 input resolution
# (rel eps 2^-8), never compounded.

BF16_TOL = dict(rtol=3e-2, atol=1e-3)


@pytest.mark.parametrize("mode", ["elementwise", "mxu"])
def test_bf16_pack_oracle_parity(rng, mode):
    """bf16-stored packs vs the f32 dense oracle, both compute modes,
    per-pair and batched kernels."""
    n = 32
    a, e = _sparse_pair(rng, n, density=0.15)
    ap, ep = _sparse_pair(rng, n, density=0.15)
    P = rng.random((n, n)).astype(np.float32)
    ref = _oracle(a, e, ap, ep, P)
    ek_pack = EK if mode == "mxu" else None
    p1 = pack_graph_row_panels(a, e, edge_kernel=ek_pack,
                               pack_dtype=jnp.bfloat16)
    p2 = pack_graph_row_panels(ap, ep, edge_kernel=ek_pack,
                               pack_dtype=jnp.bfloat16)
    assert p1.values_adj.dtype == jnp.bfloat16
    assert p1.values_lab.dtype == jnp.bfloat16
    if mode == "mxu":
        assert p1.values_w.dtype == jnp.bfloat16
    y = xmv_row_panel(p1, p2, jnp.asarray(P), EK, mode=mode)
    assert y.dtype == jnp.float32    # f32 accumulators, f32 output
    np.testing.assert_allclose(np.asarray(y), ref, **BF16_TOL)


def test_bf16_batched_and_solve_parity(masked_batch):
    """Whole-bucket bf16 packs: batched kernel vs f32 packs, and the
    end-to-end MGK solve at appropriately loosened tolerance."""
    g1, g2 = masked_batch
    from repro.kernels.xmv_block_sparse import resolve_pack_dtype
    assert resolve_pack_dtype("bfloat16") == resolve_pack_dtype(
        jnp.bfloat16)
    p1f = row_panel_packs_for_batch(g1, edge_kernel=EK)
    p2f = row_panel_packs_for_batch(g2, edge_kernel=EK)
    p1b = row_panel_packs_for_batch(g1, edge_kernel=EK,
                                    pack_dtype=jnp.bfloat16)
    p2b = row_panel_packs_for_batch(g2, edge_kernel=EK,
                                    pack_dtype=jnp.bfloat16)
    # halved value-buffer footprint is the point: assert it
    assert p1b.values_adj.nbytes * 2 == p1f.values_adj.nbytes
    assert p1b.values_w.nbytes * 2 == p1f.values_w.nbytes
    P = _random_p(g1, g2)
    for mode in ("elementwise", "mxu"):
        yf = xmv_row_panel_batched(p1f, p2f, P, EK, mode=mode)
        yb = xmv_row_panel_batched(p1b, p2b, P, EK, mode=mode)
        np.testing.assert_allclose(np.asarray(yb), np.asarray(yf),
                                   err_msg=mode, **BF16_TOL)
    rf = mgk_pairs_sparse(g1, g2, p1f, p2f, VK, EK, tol=1e-8)
    rb = mgk_pairs_sparse(g1, g2, p1b, p2b, VK, EK, tol=1e-8)
    np.testing.assert_allclose(np.asarray(rb.values),
                               np.asarray(rf.values), **BF16_TOL)
    # and with the kron preconditioner riding along
    rk = mgk_pairs_sparse(g1, g2, p1b, p2b, VK, EK, tol=1e-8,
                          precond="kron")
    np.testing.assert_allclose(np.asarray(rk.values),
                               np.asarray(rf.values), **BF16_TOL)
