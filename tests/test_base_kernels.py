"""Base kernels: elementwise vs feature-expansion equivalence, ranges,
positive-definiteness."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.base_kernels import (CompactPolynomial, Constant,
                                     KroneckerDelta, SquareExponential)

KERNELS = [Constant(1.0), KroneckerDelta(0.5, n_labels=8),
           SquareExponential(1.0, rank=12), CompactPolynomial(1.0)]


@pytest.mark.parametrize("k", KERNELS, ids=lambda k: type(k).__name__)
def test_range_and_symmetry(k, rng):
    if isinstance(k, KroneckerDelta):
        x = rng.integers(0, 8, 64).astype(np.float32)
        y = rng.integers(0, 8, 64).astype(np.float32)
    else:
        x = rng.random(64).astype(np.float32)
        y = rng.random(64).astype(np.float32)
    vxy = np.asarray(k(jnp.asarray(x), jnp.asarray(y)))
    vyx = np.asarray(k(jnp.asarray(y), jnp.asarray(x)))
    assert np.allclose(vxy, vyx, atol=1e-7)
    assert (vxy >= 0).all() and (vxy <= 1 + 1e-6).all()
    # kappa(x, x) == 1 for these kernels
    vxx = np.asarray(k(jnp.asarray(x), jnp.asarray(x)))
    assert np.allclose(vxx, 1.0, atol=1e-6)


@pytest.mark.parametrize("k", [Constant(0.7), KroneckerDelta(0.3, 8),
                               SquareExponential(2.0, rank=16)],
                         ids=lambda k: type(k).__name__)
def test_feature_expansion_matches_elementwise(k, rng):
    if isinstance(k, KroneckerDelta):
        x = rng.integers(0, 8, 32).astype(np.float32)
        y = rng.integers(0, 8, 32).astype(np.float32)
    else:
        x = rng.random(32).astype(np.float32)
        y = rng.random(32).astype(np.float32)
    direct = np.asarray(k(jnp.asarray(x)[:, None], jnp.asarray(y)[None, :]))
    phi_x = np.asarray(k.features(jnp.asarray(x)))
    phi_y = np.asarray(k.features(jnp.asarray(y)))
    via_features = phi_x @ phi_y.T
    assert np.allclose(direct, via_features, atol=2e-6), \
        np.abs(direct - via_features).max()


@settings(max_examples=30, deadline=None)
@given(alpha=st.floats(0.1, 4.0), x=st.floats(0, 1), y=st.floats(0, 1))
def test_se_truncation_error_bound(alpha, x, y):
    k = SquareExponential(alpha, rank=12)
    direct = float(k(jnp.float32(x), jnp.float32(y)))
    fx = np.asarray(k.features(jnp.float32(x)))
    fy = np.asarray(k.features(jnp.float32(y)))
    assert abs(direct - float(fx @ fy)) < 1e-4


@pytest.mark.parametrize("k", [KroneckerDelta(0.5, 8),
                               SquareExponential(1.0, rank=12)],
                         ids=lambda k: type(k).__name__)
def test_kernel_matrix_psd(k, rng):
    if isinstance(k, KroneckerDelta):
        x = rng.integers(0, 8, 40).astype(np.float32)
    else:
        x = rng.random(40).astype(np.float32)
    K = np.asarray(k(jnp.asarray(x)[:, None], jnp.asarray(x)[None, :]))
    w = np.linalg.eigvalsh(K.astype(np.float64))
    assert w.min() > -1e-5
