"""Kronecker-factored preconditioner (core/precond.py, DESIGN.md §9):

* property suite — factors/assembled M^{-1} are SPD, the batched apply
  matches the dense Kronecker-inverse oracle (core/xmv.py), and
  PCG-with-kron converges to the SAME solution as Jacobi on
  hypothesis-generated graph pairs across all four adaptive routes;
* the tolerance-semantics contract — segmented and lockstep solvers
  declare convergence on the identical preconditioned-residual
  criterion under any ``precond=`` (iterate-for-iterate pin with
  ``precond="kron"``, both PCG variants);
* the point of the subsystem — kron reaches tolerance in FEWER
  iterations than Jacobi on a dense bucket (the BENCH_pcg contract in
  miniature).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import (Constant, CompactPolynomial, KroneckerDelta,
                        SquareExponential, batch_from_graphs)
from repro.core.graph import Graph
from repro.core.mgk import (build_product_system, _make_matvec,
                            mgk_adaptive, mgk_pairs, mgk_pairs_sparse,
                            mgk_pairs_sparse_segmented)
from repro.core.pcg import pcg_solve, pcg_solve_segmented
from repro.core.precond import (kron_apply, kron_factors, kron_scalars,
                                take_kron_factors)
from repro.core.xmv import kron_precond_dense
from repro.data import make_drugbank_like_dataset

VK = Constant(1.0)
VKD = KroneckerDelta(0.4, n_labels=8)
EK = SquareExponential(0.8, rank=12)
CP = CompactPolynomial(0.9)


def _random_pair_batch(B, n, seed, p=0.3, q=0.05, pad_to=None):
    """Random dense-ish labeled graph pairs (the §9 target regime:
    small stopping probability, substantial off-diagonal mass)."""
    rng = np.random.default_rng(seed)
    gs = []
    for _ in range(2 * B):
        nn = int(rng.integers(max(4, n - 4), n + 1))
        a = (rng.random((nn, nn)) < p).astype(np.float32)
        a = np.triu(a, 1)
        a = a + a.T
        e = rng.random((nn, nn)).astype(np.float32)
        e = (e + e.T) / 2 * (a != 0)
        v = rng.integers(0, 4, nn).astype(np.float32)
        gs.append(Graph.create(a, e, v, stop_prob=q))
    pad_to = pad_to or (n + (-n) % 8)
    return (batch_from_graphs(gs[:B], pad_to=pad_to),
            batch_from_graphs(gs[B:], pad_to=pad_to))


# -- factor / oracle properties -------------------------------------------


@settings(max_examples=15, deadline=None)
@given(n=st.integers(6, 20), seed=st.integers(0, 1000),
       p=st.floats(0.1, 0.6))
def test_property_preconditioner_spd_and_matches_oracle(n, seed, p):
    """For random graph pairs: the rank-1 factors are positive, the
    assembled dense M^{-1} is symmetric positive definite (the b-clamp
    certificate), and the batched apply equals oracle @ r."""
    g1, g2 = _random_pair_batch(2, n, seed, p=p)
    B = 2
    N, M = g1.adjacency.shape[1], g2.adjacency.shape[1]
    f1, f2 = kron_factors(g1), kron_factors(g2)
    # rank-1 (diagonal) factors strictly positive
    assert np.all(np.asarray(f1.dinv) > 0)
    assert np.all(np.asarray(f2.dinv) > 0)
    # the similarity row-sum bound keeps sigma < 1 for q > 0
    assert np.all(np.asarray(f1.sigma) < 1.0)
    a, b = kron_scalars(f1, f2, VK, EK)
    assert np.all(np.asarray(b) >= 0)
    apply_ = kron_apply(f1, f2, VK, EK, (B, N, M))
    rng = np.random.default_rng(seed + 1)
    r = jnp.asarray(rng.standard_normal((B, N * M)).astype(np.float32))
    z = np.asarray(apply_(r))
    for i in range(B):
        fi = jax.tree.map(lambda x: x[i], f1)
        fj = jax.tree.map(lambda x: x[i], f2)
        Minv = np.asarray(kron_precond_dense(fi, fj, a[i], b[i]))
        np.testing.assert_allclose(Minv, Minv.T, atol=1e-6)
        ev = np.linalg.eigvalsh(Minv)
        assert ev.min() > 0, f"M^-1 not PD: min eig {ev.min()}"
        np.testing.assert_allclose(z[i], Minv @ np.asarray(r[i]),
                                   rtol=2e-4, atol=1e-6)


def test_rank1_is_diagonal_mean_field():
    """kron_rank=1 keeps only the diagonal Kronecker term — the apply
    must be elementwise (a * dinv ⊗ dinv')."""
    g1, g2 = _random_pair_batch(2, 10, 3)
    B, N, M = 2, g1.adjacency.shape[1], g2.adjacency.shape[1]
    f1, f2 = kron_factors(g1), kron_factors(g2)
    a, _ = kron_scalars(f1, f2, VK, EK)
    apply1 = kron_apply(f1, f2, VK, EK, (B, N, M), rank=1)
    r = jnp.asarray(np.random.default_rng(0).random((B, N * M),)
                    .astype(np.float32))
    dd = (np.asarray(f1.dinv)[:, :, None]
          * np.asarray(f2.dinv)[:, None, :]).reshape(B, -1)
    np.testing.assert_allclose(np.asarray(apply1(r)),
                               np.asarray(a)[:, None] * dd
                               * np.asarray(r), rtol=1e-6)
    with pytest.raises(ValueError):
        kron_apply(f1, f2, VK, EK, (B, N, M), rank=3)


# -- same solution as Jacobi on every adaptive route ----------------------


def _sparse_batches(seed=4):
    gs = [g for g in make_drugbank_like_dataset(16, seed=seed)
          if 8 <= g.n_nodes <= 30][:4]
    return (batch_from_graphs(gs[:2], pad_to=32),
            batch_from_graphs(gs[2:4], pad_to=32))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_kron_matches_jacobi_solution_dense_routes(seed):
    """The preconditioner changes the trajectory, never the solution:
    dense routes (lowrank / pallas) at tight tolerance."""
    g1, g2 = _random_pair_batch(2, 12, seed)
    for method, ek in (("lowrank", EK), ("pallas", CP)):
        rj = mgk_pairs(g1, g2, VKD, ek, method=method, tol=1e-10)
        rk = mgk_pairs(g1, g2, VKD, ek, method=method, tol=1e-10,
                       precond="kron")
        assert bool(np.asarray(rk.converged).all())
        np.testing.assert_allclose(np.asarray(rj.values),
                                   np.asarray(rk.values), rtol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_kron_matches_jacobi_solution_sparse_routes(seed):
    """Sparse routes (row-panel VPU / MXU), drugbank-like pairs."""
    from repro.kernels.ops import row_panel_packs_for_batch
    g1, g2 = _sparse_batches(seed=4 + seed % 3)
    for mode, ek_pack in (("elementwise", None), ("mxu", EK)):
        p1 = row_panel_packs_for_batch(g1, edge_kernel=ek_pack)
        p2 = row_panel_packs_for_batch(g2, edge_kernel=ek_pack)
        rj = mgk_pairs_sparse(g1, g2, p1, p2, VKD, EK,
                              sparse_mode=mode, tol=1e-10)
        rk = mgk_pairs_sparse(g1, g2, p1, p2, VKD, EK,
                              sparse_mode=mode, tol=1e-10,
                              precond="kron")
        assert bool(np.asarray(rk.converged).all())
        np.testing.assert_allclose(np.asarray(rj.values),
                                   np.asarray(rk.values), rtol=1e-5)


def test_adaptive_routes_accept_precond():
    """mgk_adaptive threads precond to whichever backend wins."""
    g1, g2 = _sparse_batches()
    rj = mgk_adaptive(g1, g2, VKD, EK, tol=1e-10)
    rk = mgk_adaptive(g1, g2, VKD, EK, tol=1e-10, precond="kron")
    np.testing.assert_allclose(np.asarray(rj.values),
                               np.asarray(rk.values), rtol=1e-5)
    d1, d2 = _random_pair_batch(2, 12, 0)
    rjd = mgk_adaptive(d1, d2, VKD, EK, tol=1e-10)
    rkd = mgk_adaptive(d1, d2, VKD, EK, tol=1e-10, precond="kron")
    np.testing.assert_allclose(np.asarray(rjd.values),
                               np.asarray(rkd.values), rtol=1e-5)


def test_unknown_precond_raises():
    g1, g2 = _random_pair_batch(1, 8, 0)
    with pytest.raises(ValueError):
        mgk_pairs(g1, g2, VK, EK, method="lowrank", precond="ilu")


# -- the iteration win (the point of the subsystem) -----------------------


def test_kron_cuts_iterations_on_dense_bucket():
    """On the dense small-q regime the rank-2 preconditioner must beat
    Jacobi by a wide margin (BENCH_pcg asserts ≥30% at bench scale)."""
    g1, g2 = _random_pair_batch(4, 20, 7, p=0.35, q=0.05)
    rj = mgk_pairs(g1, g2, VK, EK, method="lowrank", tol=1e-6)
    rk = mgk_pairs(g1, g2, VK, EK, method="lowrank", tol=1e-6,
                   precond="kron")
    ij = int(np.asarray(rj.iterations).sum())
    ik = int(np.asarray(rk.iterations).sum())
    assert bool(np.asarray(rk.converged).all())
    assert ik < ij, (ij, ik)
    assert 1.0 - ik / ij >= 0.25, f"only {1 - ik / ij:.1%} reduction"
    # rank-1 (mean-field Jacobi) must not beat rank-2
    r1 = mgk_pairs(g1, g2, VK, EK, method="lowrank", tol=1e-6,
                   precond="kron", kron_rank=1)
    assert int(np.asarray(r1.iterations).sum()) >= ik


# -- tolerance semantics: one criterion, every variant, every solver ------


@pytest.mark.parametrize("variant", ["classic", "pipelined"])
def test_segmented_matches_lockstep_with_kron(variant, rng):
    """Iterate-for-iterate pin under precond='kron' (the §9 factor
    remap through the survivor gather), both PCG variants."""
    from repro.kernels.ops import row_panel_packs_for_batch, \
        take_row_panel_pack
    g1, g2 = _sparse_batches()
    p1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
    p2 = row_panel_packs_for_batch(g2, edge_kernel=EK)
    lock = mgk_pairs_sparse(g1, g2, p1, p2, VKD, EK, tol=1e-10,
                            precond="kron", pcg_variant=variant)
    seg = mgk_pairs_sparse_segmented(g1, g2, p1, p2, VKD, EK, tol=1e-10,
                                     segment_size=4, precond="kron",
                                     pcg_variant=variant)
    assert np.array_equal(np.asarray(lock.iterations),
                          np.asarray(seg.iterations))
    np.testing.assert_allclose(np.asarray(seg.values),
                               np.asarray(lock.values), rtol=1e-6)
    assert int(seg.matvec_pairs) <= int(lock.matvec_pairs)


def test_segmented_gram_tile_with_kron():
    """Gram-tile lockstep vs segmented retirement under kron: the
    per-axis factors expand to per-pair factors alongside the packs."""
    from repro.kernels.ops import row_panel_packs_for_batch
    g1, g2 = _sparse_batches()
    Bi = Bj = 2
    g1u = jax.tree.map(lambda x: x[:Bi], g1)
    g2u = jax.tree.map(lambda x: x[:Bj], g2)
    g1f = jax.tree.map(lambda x: jnp.repeat(x, Bj, axis=0), g1u)
    g2f = jax.tree.map(
        lambda x: jnp.tile(x, (Bi,) + (1,) * (x.ndim - 1)), g2u)
    a1 = row_panel_packs_for_batch(g1u, edge_kernel=EK)
    a2 = row_panel_packs_for_batch(g2u, edge_kernel=EK)
    lock = mgk_pairs_sparse(g1f, g2f, a1, a2, VKD, EK, tol=1e-10,
                            gram_tile=(Bi, Bj), precond="kron")
    seg = mgk_pairs_sparse_segmented(
        g1f, g2f, a1, a2, VKD, EK, tol=1e-10, segment_size=3,
        gram_tile=(Bi, Bj), precond="kron")
    assert np.array_equal(np.asarray(lock.iterations),
                          np.asarray(seg.iterations))
    assert int(seg.matvec_pairs) <= int(lock.matvec_pairs)
    np.testing.assert_allclose(np.asarray(seg.values),
                               np.asarray(lock.values), rtol=1e-6)


def test_classic_and_pipelined_agree_under_kron():
    """The preconditioned-residual criterion is the IDENTICAL quantity
    in both recurrences (classic rho == pipelined gamma), so iteration
    counts agree within the s-recurrence drift (±1) under kron exactly
    as they do under Jacobi."""
    g1, g2 = _random_pair_batch(3, 14, 11)
    sys_ = build_product_system(g1, g2, VK)
    mv = _make_matvec(g1, g2, sys_, EK, "full", 8)
    f1, f2 = kron_factors(g1), kron_factors(g2)
    B, n = g1.adjacency.shape[0], g1.adjacency.shape[1]
    m = g2.adjacency.shape[1]
    papply = kron_apply(f1, f2, VK, EK, (B, n, m))
    rhs = sys_.dx * sys_.qx
    diag = sys_.dx / sys_.vx
    rc = pcg_solve(mv, rhs, diag, tol=1e-8, precond_apply=papply)
    rp = pcg_solve(mv, rhs, diag, tol=1e-8, precond_apply=papply,
                   variant="pipelined")
    gap = np.abs(np.asarray(rc.iterations)
                 - np.asarray(rp.iterations)).max()
    assert int(gap) <= 1
    np.testing.assert_allclose(np.asarray(rc.x), np.asarray(rp.x),
                               rtol=2e-3, atol=1e-6)


def test_segmented_generic_solver_precond_apply(rng):
    """pcg_solve_segmented with a generic SPD precond_apply and a
    select that rebuilds it: identical iterates to lockstep (the
    solver-level half of the tolerance-semantics contract)."""
    B, N = 6, 16
    a = rng.random((B, N, N)).astype(np.float32)
    spd = np.einsum("bij,bkj->bik", a, a) \
        + N * np.eye(N, dtype=np.float32)[None]
    # spread convergence speeds so retirement actually happens
    spd *= (1.0 + 4.0 * np.arange(B)[:, None, None] / B)
    b = rng.random((B, N)).astype(np.float32)
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    # a simple SPD non-diagonal preconditioner: tridiagonal-ish damp
    m_inv = np.linalg.inv(spd * np.eye(N)[None]
                          + 0.1 * spd * (np.abs(
                              np.arange(N)[:, None]
                              - np.arange(N)[None, :]) == 1))
    m_inv = 0.5 * (m_inv + np.swapaxes(m_inv, 1, 2))
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)      # noqa: E731
    ap = lambda r: jnp.einsum("bij,bj->bi", m_inv, r)    # noqa: E731

    def select(lanes):
        idx = np.asarray(lanes)
        sub = spd[idx]
        sub_m = m_inv[idx]
        return (lambda p: jnp.einsum("bij,bj->bi", sub, p),
                lambda r: jnp.einsum("bij,bj->bi", sub_m, r))

    lock = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-9,
                     precond_apply=ap)
    seg = pcg_solve_segmented(mv, jnp.asarray(b), diag, tol=1e-9,
                              segment_size=3, select=select,
                              precond_apply=ap)
    assert np.array_equal(np.asarray(lock.iterations),
                          np.asarray(seg.iterations))
    np.testing.assert_allclose(np.asarray(seg.x), np.asarray(lock.x),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(seg.residual),
                               np.asarray(lock.residual),
                               rtol=1e-5, atol=1e-30)
