"""Gradcheck: the adjoint-PCG custom VJP vs central finite differences,
for EVERY dispatch path of the mgk_adaptive table (DESIGN.md §3.4/§7) —
dense tiling&blocking (pallas), dense low-rank MXU, sparse row-panel
VPU, sparse row-panel MXU — plus the jnp reference backends, over
vertex-kernel params, edge-kernel params, and the stopping probability
``q``. Also pins the cost contract: the gradient jaxpr contains exactly
TWO PCG solves (forward + adjoint)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.core import (CompactPolynomial, KroneckerDelta,
                        SquareExponential, batch_from_graphs,
                        kernel_theta, mgk_adaptive_value_and_grad,
                        mgk_value_fn)
from repro.core.mgk import adaptive_route
from repro.data import make_drugbank_like_dataset, make_synthetic_dataset
from repro.kernels.ops import row_panel_packs_for_batch

VK = KroneckerDelta(0.4, n_labels=8)
SE = SquareExponential(1.2, rank=12)
CP = CompactPolynomial(0.9)

RTOL = 1e-3          # the acceptance bar
ATOL = 2e-5          # f32 central-difference noise floor


def _dense_batches():
    gs = make_synthetic_dataset("nws", n_graphs=4, n_nodes=12, seed=0,
                                stop_prob=0.2)
    return (batch_from_graphs(gs[:2], pad_to=16),
            batch_from_graphs(gs[2:], pad_to=16))


def _sparse_batches():
    gs = [g for g in make_drugbank_like_dataset(14, seed=4)
          if 8 <= g.n_nodes <= 30][:4]
    return (batch_from_graphs(gs[:2], pad_to=32),
            batch_from_graphs(gs[2:], pad_to=32))


def gradcheck(fn, theta, h0=3e-3, rtol=RTOL, atol=ATOL):
    """Central finite differences of fn(theta).sum() vs jax.grad through
    the custom VJP, leaf by leaf."""
    f = lambda t: fn(t).sum()                          # noqa: E731
    grads = jax.grad(f)(theta)
    leaves, treedef = jtu.tree_flatten(theta)
    grad_leaves = jtu.tree_flatten(grads)[0]
    assert len(leaves) == len(grad_leaves)
    for i, leaf in enumerate(leaves):
        h = h0 * max(1.0, abs(float(leaf)))
        plus, minus = list(leaves), list(leaves)
        plus[i] = leaf + h
        minus[i] = leaf - h
        fd = (float(f(jtu.tree_unflatten(treedef, plus)))
              - float(f(jtu.tree_unflatten(treedef, minus)))) / (2 * h)
        an = float(grad_leaves[i])
        assert an == pytest.approx(fd, rel=rtol, abs=atol), \
            f"leaf {i}: FD {fd} vs adjoint {an}"


# -- dense dispatch paths --------------------------------------------------

@pytest.mark.parametrize("method,ek", [
    ("full", SE),
    ("elementwise", SE),
    ("lowrank", SE),          # adaptive: dense + expansion
    ("pallas", CP),           # adaptive: dense, no expansion
    ("pallas", SE),           # theta threading through the dense kernel
], ids=["full-se", "elementwise-se", "lowrank-se", "pallas-cp",
        "pallas-se"])
def test_dense_paths_match_fd(method, ek):
    g1, g2 = _dense_batches()
    fn = mgk_value_fn(g1, g2, VK, ek, method=method, tol=1e-12)
    gradcheck(fn, kernel_theta(VK, ek, q=0.2))


# -- sparse dispatch paths -------------------------------------------------

@pytest.mark.parametrize("mode,ek", [
    ("elementwise", CP),      # adaptive: sparse, no expansion (VPU)
    ("elementwise", SE),
    ("mxu", SE),              # adaptive: sparse + expansion (MXU)
], ids=["vpu-cp", "vpu-se", "mxu-se"])
def test_sparse_paths_match_fd(mode, ek):
    g1, g2 = _sparse_batches()
    ek_pack = ek if mode == "mxu" else None
    p1 = row_panel_packs_for_batch(g1, edge_kernel=ek_pack)
    p2 = row_panel_packs_for_batch(g2, edge_kernel=ek_pack)
    fn = mgk_value_fn(g1, g2, VK, ek, method="sparse", packs1=p1,
                      packs2=p2, sparse_mode=mode, tol=1e-12)
    gradcheck(fn, kernel_theta(VK, ek, q=0.05))


def test_adaptive_entry_covers_all_routes():
    """mgk_adaptive_value_and_grad routes through the real dispatch
    table; both a dense and a sparse batch must produce per-pair grads
    for every theta group."""
    gs = [g for g in make_drugbank_like_dataset(14, seed=4)
          if 8 <= g.n_nodes <= 30][:4]
    sparse_wide = (batch_from_graphs(gs[:2], pad_to=64),
                   batch_from_graphs(gs[2:], pad_to=64))
    dense = (_dense_batches(), SE, "lowrank")
    sparse = (sparse_wide, CP, "sparse_vpu")
    for (g1, g2), ek, expected_route in (dense, sparse):
        route, _ = adaptive_route(g1, g2, ek)
        assert route == expected_route
        vals, grads = mgk_adaptive_value_and_grad(g1, g2, VK, ek, q=0.1)
        B = g1.adjacency.shape[0]
        assert vals.shape == (B,)
        assert set(grads) == {"vertex", "edge", "q"}
        for leaf in jtu.tree_leaves(grads):
            assert leaf.shape == (B,)
            assert np.all(np.isfinite(np.asarray(leaf)))


def test_per_pair_grads_sum_to_vjp():
    """The batch VJP (jax.grad of the sum) must equal the sum of the
    per-pair gradients — same adjoint solve, two reductions."""
    g1, g2 = _dense_batches()
    fn = mgk_value_fn(g1, g2, VK, SE, method="lowrank", tol=1e-12)
    theta = kernel_theta(VK, SE, q=0.2)
    total = jax.grad(lambda t: fn(t).sum())(theta)
    _, per_pair = fn.value_and_pair_grads(theta)
    summed = jax.tree.map(lambda a: jnp.sum(a, axis=0), per_pair)
    for a, b in zip(jtu.tree_leaves(total), jtu.tree_leaves(summed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


# -- Kronecker-preconditioned solves (DESIGN.md §9) ------------------------
#
# precond="kron" changes the PCG trajectory, never the solution, so FD
# parity must hold unchanged on every dispatch route — forward AND
# adjoint solve share the identical SPD M^{-1} closure.

@pytest.mark.parametrize("route", ["lowrank", "pallas", "sparse-vpu",
                                   "sparse-mxu"])
def test_kron_precond_paths_match_fd(route):
    if route in ("lowrank", "pallas"):
        g1, g2 = _dense_batches()
        ek = SE if route == "lowrank" else CP
        fn = mgk_value_fn(g1, g2, VK, ek, method=route, tol=1e-12,
                          precond="kron")
        gradcheck(fn, kernel_theta(VK, ek, q=0.2))
        return
    g1, g2 = _sparse_batches()
    mode = "mxu" if route == "sparse-mxu" else "elementwise"
    ek = SE if mode == "mxu" else CP
    ek_pack = ek if mode == "mxu" else None
    p1 = row_panel_packs_for_batch(g1, edge_kernel=ek_pack)
    p2 = row_panel_packs_for_batch(g2, edge_kernel=ek_pack)
    fn = mgk_value_fn(g1, g2, VK, ek, method="sparse", packs1=p1,
                      packs2=p2, sparse_mode=mode, tol=1e-12,
                      precond="kron")
    gradcheck(fn, kernel_theta(VK, ek, q=0.05))


def test_kron_adaptive_entry_matches_jacobi_grads():
    """mgk_adaptive_value_and_grad with precond='kron' must produce the
    same per-pair gradients as Jacobi (identical solutions at tight
    tolerance) on both a dense- and a sparse-routed batch."""
    for batches in (_dense_batches(), _sparse_batches()):
        g1, g2 = batches
        vj, gj = mgk_adaptive_value_and_grad(g1, g2, VK, SE, q=0.1,
                                             tol=1e-12)
        vk, gk = mgk_adaptive_value_and_grad(g1, g2, VK, SE, q=0.1,
                                             tol=1e-12, precond="kron")
        np.testing.assert_allclose(np.asarray(vj), np.asarray(vk),
                                   rtol=1e-6)
        for a, b in zip(jtu.tree_leaves(gj), jtu.tree_leaves(gk)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-7)


# -- the cost contract: exactly two PCG solves -----------------------------

def _count_pcg_solves(jaxpr, acc=0):
    """while-loop primitives OUTSIDE pallas kernels == PCG solves (the
    in-kernel fori_loops of the row-panel kernel live inside the
    pallas_call param and are skipped)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "while":
            acc += 1
        if "pallas" in eqn.primitive.name:
            continue
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                acc = _count_pcg_solves(v.jaxpr, acc)
            elif hasattr(v, "eqns"):
                acc = _count_pcg_solves(v, acc)
    return acc


@pytest.mark.parametrize("make", [
    lambda: (mgk_value_fn(*_dense_batches(), VK, SE, method="lowrank"),
             kernel_theta(VK, SE, q=0.2)),
    lambda: (mgk_value_fn(*_dense_batches(), VK, CP, method="pallas"),
             kernel_theta(VK, CP, q=0.2)),
    lambda: (mgk_value_fn(
        *_sparse_batches(), VK, SE, method="sparse",
        packs1=row_panel_packs_for_batch(_sparse_batches()[0],
                                         edge_kernel=SE),
        packs2=row_panel_packs_for_batch(_sparse_batches()[1],
                                         edge_kernel=SE),
        sparse_mode="mxu"), kernel_theta(VK, SE, q=0.05)),
], ids=["lowrank", "pallas", "sparse-mxu"])
def test_exactly_two_pcg_solves_in_grad_jaxpr(make):
    fn, theta = make()
    jaxpr = jax.make_jaxpr(jax.grad(lambda t: fn(t).sum()))(theta)
    assert _count_pcg_solves(jaxpr.jaxpr) == 2


def test_exactly_two_pcg_solves_with_kron_precond():
    """The §9 preconditioner must not add solves: the gradient jaxpr
    still contains exactly two while-loop PCG solves (the M^{-1}
    applications live INSIDE the loop bodies)."""
    g1, g2 = _sparse_batches()
    p1 = row_panel_packs_for_batch(g1, edge_kernel=SE)
    p2 = row_panel_packs_for_batch(g2, edge_kernel=SE)
    for spec in (dict(method="lowrank"),
                 dict(method="sparse", packs1=p1, packs2=p2,
                      sparse_mode="mxu")):
        gd, gs = _dense_batches() if spec["method"] == "lowrank" \
            else (g1, g2)
        fn = mgk_value_fn(gd, gs, VK, SE, precond="kron", **spec)
        theta = kernel_theta(VK, SE, q=0.1)
        jaxpr = jax.make_jaxpr(jax.grad(lambda t: fn(t).sum()))(theta)
        assert _count_pcg_solves(jaxpr.jaxpr) == 2


def test_value_matches_nondifferentiable_path():
    """The custom-VJP forward must be bit-compatible (to solver
    tolerance) with the plain mgk_pairs value."""
    from repro.core import mgk_pairs
    g1, g2 = _dense_batches()
    fn = mgk_value_fn(g1, g2, VK, SE, method="lowrank", tol=1e-12)
    vals = fn(kernel_theta(VK, SE))
    ref = mgk_pairs(g1, g2, VK, SE, method="lowrank", tol=1e-12).values
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ref),
                               rtol=1e-6)
