"""Batched masked PCG vs LAPACK."""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.pcg import pcg_solve


def _spd_batch(rng, B, N):
    a = rng.random((B, N, N)).astype(np.float32)
    spd = np.einsum("bij,bkj->bik", a, a) + \
        N * np.eye(N, dtype=np.float32)[None]
    return spd


def test_matches_direct_solve(rng):
    B, N = 4, 24
    spd = _spd_batch(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    res = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-10, max_iter=500)
    x_ref = np.stack([np.linalg.solve(spd[i], b[i]) for i in range(B)])
    assert bool(res.converged.all())
    np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=2e-3,
                               atol=2e-4)


def test_preconditioner_helps(rng):
    B, N = 2, 32
    spd = _spd_batch(rng, B, N)
    # badly scaled diagonal
    scale = np.diag(np.logspace(0, 3, N).astype(np.float32))
    spd = np.einsum("ij,bjk,kl->bil", scale, spd, scale)
    b = rng.random((B, N)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    with_pc = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-8, max_iter=2000)
    without = pcg_solve(mv, jnp.asarray(b), jnp.ones_like(diag), tol=1e-8,
                        max_iter=2000)
    assert int(with_pc.iterations.max()) < int(without.iterations.max())


def test_batch_equals_individual(rng):
    """Masked lockstep batching must not change any member's solution."""
    B, N = 3, 16
    spd = _spd_batch(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    diag = np.einsum("bii->bi", spd)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    batched = pcg_solve(mv, jnp.asarray(b), jnp.asarray(diag), tol=1e-10)
    for i in range(B):
        mv1 = lambda p: jnp.einsum("bij,bj->bi", spd[i:i + 1], p)  # noqa
        single = pcg_solve(mv1, jnp.asarray(b[i:i + 1]),
                           jnp.asarray(diag[i:i + 1]), tol=1e-10)
        np.testing.assert_allclose(np.asarray(batched.x[i]),
                                   np.asarray(single.x[0]), rtol=2e-4,
                                   atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 32), seed=st.integers(0, 1000))
def test_property_solves_spd(n, seed):
    rng = np.random.default_rng(seed)
    spd = _spd_batch(rng, 1, n)
    b = rng.random((1, n)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    res = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-9, max_iter=400)
    resid = np.asarray(mv(res.x))[0] - b[0]
    assert np.linalg.norm(resid) < 1e-3 * max(np.linalg.norm(b[0]), 1.0)
