"""Batched masked PCG vs LAPACK, and the PR-6 numerical guards."""
import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.pcg import MatvecFault, PCG_NONFINITE, PCG_RESTARTED, \
    pcg_solve, status_names


def _spd_batch(rng, B, N):
    a = rng.random((B, N, N)).astype(np.float32)
    spd = np.einsum("bij,bkj->bik", a, a) + \
        N * np.eye(N, dtype=np.float32)[None]
    return spd


def test_matches_direct_solve(rng):
    B, N = 4, 24
    spd = _spd_batch(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    res = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-10, max_iter=500)
    x_ref = np.stack([np.linalg.solve(spd[i], b[i]) for i in range(B)])
    assert bool(res.converged.all())
    np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=2e-3,
                               atol=2e-4)


def test_preconditioner_helps(rng):
    B, N = 2, 32
    spd = _spd_batch(rng, B, N)
    # badly scaled diagonal
    scale = np.diag(np.logspace(0, 3, N).astype(np.float32))
    spd = np.einsum("ij,bjk,kl->bil", scale, spd, scale)
    b = rng.random((B, N)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    with_pc = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-8, max_iter=2000)
    without = pcg_solve(mv, jnp.asarray(b), jnp.ones_like(diag), tol=1e-8,
                        max_iter=2000)
    assert int(with_pc.iterations.max()) < int(without.iterations.max())


def test_batch_equals_individual(rng):
    """Masked lockstep batching must not change any member's solution."""
    B, N = 3, 16
    spd = _spd_batch(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    diag = np.einsum("bii->bi", spd)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    batched = pcg_solve(mv, jnp.asarray(b), jnp.asarray(diag), tol=1e-10)
    for i in range(B):
        mv1 = lambda p: jnp.einsum("bij,bj->bi", spd[i:i + 1], p)  # noqa
        single = pcg_solve(mv1, jnp.asarray(b[i:i + 1]),
                           jnp.asarray(diag[i:i + 1]), tol=1e-10)
        np.testing.assert_allclose(np.asarray(batched.x[i]),
                                   np.asarray(single.x[0]), rtol=2e-4,
                                   atol=2e-5)


def test_guard_clean_path_bitwise_parity(rng):
    """Guards must be free on clean trajectories: guard on/off at a
    fixed trip count produces bit-identical iterates (the detection
    reads scalars the iteration already computes; restart is behind a
    cond that never fires)."""
    B, N = 3, 16
    spd = _spd_batch(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    for variant in ("classic", "pipelined"):
        on = pcg_solve(mv, jnp.asarray(b), diag, fixed_iters=20,
                       variant=variant, guard=True)
        off = pcg_solve(mv, jnp.asarray(b), diag, fixed_iters=20,
                        variant=variant, guard=False)
        assert np.array_equal(np.asarray(on.x), np.asarray(off.x)), \
            variant
        assert int(np.asarray(on.status).max()) == 0


def test_guard_transient_fault_restarts_and_recovers(rng):
    """A NaN injected into the matvec for a few iterations must be
    detected, flagged, healed by residual-replacement restart — and must
    not perturb the other lanes of the batch."""
    B, N = 4, 24
    spd = _spd_batch(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    x_ref = np.stack([np.linalg.solve(spd[i], b[i]) for i in range(B)])
    for variant in ("classic", "pipelined"):
        fault = MatvecFault(pairs=(0,), start=2, stop=4,
                            value=float("nan"))
        res = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-10, max_iter=500,
                        variant=variant, fault=fault)
        status = np.asarray(res.status)
        assert status[0] & (PCG_NONFINITE | PCG_RESTARTED), \
            (variant, status_names(int(status[0])))
        assert not (status[1:] != 0).any(), variant
        assert bool(np.asarray(res.converged).all()), variant
        np.testing.assert_allclose(np.asarray(res.x), x_ref, rtol=2e-3,
                                   atol=2e-4)


def test_guard_persistent_fault_freezes_pair(rng):
    """A fault that never clears exhausts the restart budget: the sick
    pair is frozen (dead, not converged, cause recorded) while the rest
    of the batch still converges to the right answer — no NaN ever
    leaks into the healthy lanes."""
    B, N = 3, 16
    spd = _spd_batch(rng, B, N)
    b = rng.random((B, N)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    x_ref = np.stack([np.linalg.solve(spd[i], b[i]) for i in range(B)])
    for variant in ("classic", "pipelined"):
        fault = MatvecFault(pairs=(1,), start=0, stop=10**6,
                            value=float("nan"))
        res = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-10, max_iter=500,
                        variant=variant, fault=fault)
        status = np.asarray(res.status)
        conv = np.asarray(res.converged)
        assert status[1] & PCG_NONFINITE, variant
        assert not conv[1], variant
        assert conv[0] and conv[2], variant
        for i in (0, 2):
            assert np.isfinite(np.asarray(res.x[i])).all(), variant
            np.testing.assert_allclose(np.asarray(res.x[i]), x_ref[i],
                                       rtol=2e-3, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 32), seed=st.integers(0, 1000))
def test_property_solves_spd(n, seed):
    rng = np.random.default_rng(seed)
    spd = _spd_batch(rng, 1, n)
    b = rng.random((1, n)).astype(np.float32)
    mv = lambda p: jnp.einsum("bij,bj->bi", spd, p)  # noqa: E731
    diag = jnp.asarray(np.einsum("bii->bi", spd))
    res = pcg_solve(mv, jnp.asarray(b), diag, tol=1e-9, max_iter=400)
    resid = np.asarray(mv(res.x))[0] - b[0]
    assert np.linalg.norm(resid) < 1e-3 * max(np.linalg.norm(b[0]), 1.0)
