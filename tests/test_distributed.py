"""Distributed runtime: scheduling, checkpoint/restart, elasticity,
end-to-end Gram driver."""
import json
import os

import numpy as np
import pytest
import jax
from jax.sharding import Mesh

from repro.core import KroneckerDelta, SquareExponential
from repro.data import bucket_graphs, make_drugbank_like_dataset, \
    pair_blocks
from repro.distributed import ChunkStore, GramDriver, make_plan, replan
from repro.distributed.checkpoint import load_array_checkpoint, \
    save_array_checkpoint

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=10)


def _dataset(n=10, seed=7):
    gs = [g for g in make_drugbank_like_dataset(n + 6, seed=seed)
          if g.n_nodes >= 4][:n]
    return bucket_graphs(gs, max_buckets=3)


def _mesh():
    return Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))


def test_pair_blocks_cover_all_pairs_once():
    ds = _dataset(12)
    blocks = list(pair_blocks(ds, pairs_per_block=7))
    seen = set()
    for b in blocks:
        for r, c in zip(b.rows, b.cols):
            key = (min(r, c), max(r, c))
            assert key not in seen, key
            seen.add(key)
    n = len(ds)
    assert len(seen) == n * (n + 1) // 2


def test_plan_balances_load():
    ds = _dataset(16)
    blocks = list(pair_blocks(ds, pairs_per_block=4))
    plan = make_plan(blocks, n_groups=4)
    assert plan.makespan_ratio < 1.5
    assigned = [b for q in plan.assignment for b in q]
    assert sorted(assigned) == sorted(b.block_id for b in blocks)


def test_replan_is_elastic_and_deterministic():
    ds = _dataset(12)
    blocks = list(pair_blocks(ds, pairs_per_block=4))
    done = {blocks[0].block_id, blocks[1].block_id}
    p4a = replan(blocks, done, 4)
    p4b = replan(blocks, done, 4)
    assert p4a == p4b                       # deterministic
    p2 = replan(blocks, done, 2)            # shrink fleet
    ids4 = {b for q in p4a.assignment for b in q}
    ids2 = {b for q in p2.assignment for b in q}
    assert ids4 == ids2                     # same remaining work
    assert not ids4 & done


def test_chunk_store_crc_detects_corruption(tmp_path):
    store = ChunkStore(str(tmp_path))
    store.save_block(0, rows=np.array([0]), cols=np.array([1]),
                     values=np.array([0.5]), iterations=np.array([3]))
    blk = store.load_block(0)
    assert blk["values"][0] == 0.5
    # corrupt the file
    with open(store.block_path(0), "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        store.load_block(0)


def test_chunk_store_first_writer_wins(tmp_path):
    store = ChunkStore(str(tmp_path))
    assert store.save_block(3, rows=np.array([0]), cols=np.array([1]),
                            values=np.array([1.0]),
                            iterations=np.array([1]))
    # straggler duplicate must be a no-op
    assert not store.save_block(3, rows=np.array([0]), cols=np.array([1]),
                                values=np.array([9.9]),
                                iterations=np.array([1]))
    assert store.load_block(3)["values"][0] == 1.0


def test_gram_driver_end_to_end_and_restart(tmp_path):
    ds = _dataset(8)
    store = ChunkStore(str(tmp_path))
    drv = GramDriver(ds, _mesh(), VK, EK, store=store, pairs_per_block=8)
    K = drv.run()
    assert K.shape == (8, 8)
    assert not np.isnan(K).any()
    assert np.allclose(K, K.T, atol=1e-6)
    assert np.allclose(np.diag(K), 1.0, atol=1e-5)   # normalized
    w = np.linalg.eigvalsh(K)
    assert w.min() > -1e-6
    done_before = store.done_blocks()
    K2 = drv.run()                                   # restart: no recompute
    assert store.done_blocks() == done_before
    np.testing.assert_allclose(K, K2)


def test_gram_driver_resumes_partial(tmp_path):
    ds = _dataset(8)
    store = ChunkStore(str(tmp_path))
    drv = GramDriver(ds, _mesh(), VK, EK, store=store, pairs_per_block=8)
    blocks = drv.blocks()
    # simulate a crash: precompute only the first block then "restart"
    from repro.distributed.gram import gram_pair_step, solve_pair_block
    step = gram_pair_step(_mesh(), VK, EK)
    out = solve_pair_block(ds, blocks[0], step, 1)
    store.save_block(blocks[0].block_id, **out)
    K = drv.run()       # must complete the remaining blocks
    assert not np.isnan(K).any()


def test_sparse_step_caches_packs_per_graph(monkeypatch):
    """A graph appearing in many pair blocks must be octile-decomposed
    once per bucket size, not once per block (the GraphPackCache)."""
    import repro.core.octile as octile_mod
    from repro.distributed.gram import gram_pair_step, solve_pair_block

    ds = _dataset(8)
    blocks = list(pair_blocks(ds, pairs_per_block=4))
    calls = {"n": 0}
    real_decompose = octile_mod.octile_decompose

    def counting(*a, **kw):
        calls["n"] += 1
        return real_decompose(*a, **kw)

    monkeypatch.setattr(octile_mod, "octile_decompose", counting)
    step = gram_pair_step(_mesh(), VK, EK, method="pallas_sparse")
    assert getattr(step, "wants_indices", False)
    outs = [solve_pair_block(ds, b, step, 1) for b in blocks]
    # every (graph, bucket pad) combination decomposed exactly once, plus
    # at most one dummy pack per pad size — far below once-per-block
    distinct = {(int(i), b.pad_row) for b in blocks for i in b.rows} | \
               {(int(i), b.pad_col) for b in blocks for i in b.cols}
    assert calls["n"] <= len(distinct) + len(
        {b.pad_row for b in blocks} | {b.pad_col for b in blocks})
    assert step.pack_cache.hits > 0
    # and the cached path computes the same values as the dense reference
    from repro.distributed.gram import gram_pair_step as gps
    ref_step = gps(_mesh(), VK, EK, method="lowrank")
    for b, out in zip(blocks[:2], outs[:2]):
        ref = solve_pair_block(ds, b, ref_step, 1)
        np.testing.assert_allclose(out["values"], ref["values"],
                                   rtol=1e-4)


def test_sparse_step_domain_guard_falls_back_to_elementwise():
    """sparse_mode='auto' must not use the Taylor expansion outside its
    accuracy domain — the block falls back to exact elementwise (the
    mgk_adaptive guard, applied per pair block)."""
    from repro.distributed.gram import gram_pair_step, solve_pair_block
    ds = _dataset(6)
    blocks = list(pair_blocks(ds, pairs_per_block=6))
    ek = SquareExponential(1.0, rank=10, domain=0.0)   # always out of domain
    step = gram_pair_step(_mesh(), VK, ek, method="pallas_sparse")
    out = solve_pair_block(ds, blocks[0], step, 1)
    ref_step = gram_pair_step(_mesh(), VK, ek, method="elementwise")
    ref = solve_pair_block(ds, blocks[0], ref_step, 1)
    np.testing.assert_allclose(out["values"], ref["values"], rtol=1e-4)


def test_pack_cache_lru_eviction_roundtrip():
    """The LRU bound must evict oldest entries, and eviction + re-pack
    must round-trip bit-identically (a pack is a pure function of the
    graph arrays); pack-time stats persist across eviction."""
    from repro.core.graph import batch_from_graphs
    from repro.distributed.gram import GraphPackCache
    gs = [g for g in make_drugbank_like_dataset(12, seed=3)
          if 6 <= g.n_nodes <= 32][:4]
    batch = batch_from_graphs(gs, pad_to=32)
    one = lambda b: jax.tree.map(lambda x: x[b:b + 1], batch)  # noqa

    cache = GraphPackCache(tile=8, edge_kernel=EK, max_entries=2)
    first = cache.stacked(np.array([0]), one(0))
    for b in (1, 2, 3):          # push graph 0 out of the LRU window
        cache.stacked(np.array([b]), one(b))
    assert len(cache._packs) == 2
    assert (0, 32) not in cache._packs          # evicted...
    assert cache.density(0, 32) is not None     # ...stats persist
    misses = cache.misses
    again = cache.stacked(np.array([0]), one(0))
    assert cache.misses == misses + 1           # re-packed, not cached
    for a, b in zip(first, again):
        if a is None:
            assert b is None
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_uses_measured_density_and_iterations(tmp_path):
    """The scheduler satellite: after blocks complete, plan() must feed
    the pack cache's measured octile occupancy and the store's observed
    iteration counts into estimate_cost (not the uniform defaults)."""
    from repro.distributed.scheduler import estimate_cost
    ds = _dataset(8)
    store = ChunkStore(str(tmp_path))
    drv = GramDriver(ds, _mesh(), VK, EK, store=store,
                     method="pallas_sparse", gram_tile=True,
                     tile_shape=(3, 3))
    drv.run()
    blocks = drv.blocks()
    densities = drv._block_densities(blocks)
    iters = drv._block_iters(blocks, store.done_blocks())
    assert densities and iters
    # graphs are sparse: measured occupancy must be below the uniform
    # assumption, and iteration predictions must be real CG counts
    assert all(0.0 < d <= 1.0 for d in densities.values())
    assert any(d < 1.0 for d in densities.values())
    assert all(it >= 1.0 for it in iters.values())
    bid = blocks[0].block_id
    refined = estimate_cost(blocks[0], densities[bid], iters[bid])
    assert refined != estimate_cost(blocks[0])   # defaults overridden
    # a fully-done plan is empty but the wiring must not error
    plan = drv.plan()
    assert plan.assignment == tuple([()] * plan.n_groups) or \
        plan.makespan_ratio >= 1.0


def test_gram_tile_driver_matches_per_pair_driver():
    ds = _dataset(7)
    ref = GramDriver(ds, _mesh(), VK, EK, method="pallas_sparse",
                     pairs_per_block=6).run()
    for kw in (dict(), dict(segment_size=8)):
        gt = GramDriver(ds, _mesh(), VK, EK, method="pallas_sparse",
                        gram_tile=True, tile_shape=(3, 3), **kw).run()
        np.testing.assert_allclose(gt, ref, rtol=1e-4, atol=1e-6)


def test_gram_tile_blocks_cover_all_pairs():
    ds = _dataset(11)
    from repro.data import gram_tile_blocks
    from repro.distributed.gram import _axis_structure
    blocks = list(gram_tile_blocks(ds, 3, 4))
    seen = set()
    for b in blocks:
        axes = _axis_structure(b.rows, b.cols)
        assert axes is not None      # every tile is a clean rectangle
        urows, ucols = axes
        assert len(b.rows) == len(urows) * len(ucols)
        for r, c in zip(b.rows, b.cols):
            seen.add((min(r, c), max(r, c)))
    n = len(ds)
    assert len(seen) == n * (n + 1) // 2


def test_pack_cache_rejects_non_multiple_tile():
    from repro.distributed.gram import GraphPackCache
    from repro.core.graph import batch_from_graphs
    gs = [g for g in make_drugbank_like_dataset(8, seed=1)
          if 6 <= g.n_nodes <= 24][:2]
    batch = batch_from_graphs(gs, pad_to=24)       # 24 % 16 != 0
    cache = GraphPackCache(tile=16)
    with pytest.raises(ValueError, match="multiple of"):
        cache.stacked(np.array([0, 1]), batch)


def test_gram_driver_sparse_matches_lowrank():
    ds = _dataset(6)
    drv_s = GramDriver(ds, _mesh(), VK, EK, method="pallas_sparse",
                       pairs_per_block=6)
    drv_l = GramDriver(ds, _mesh(), VK, EK, method="lowrank",
                       pairs_per_block=6)
    np.testing.assert_allclose(drv_s.run(), drv_l.run(), rtol=1e-4,
                               atol=1e-6)


def test_array_checkpoint_roundtrip_and_fallback(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": (np.ones(4), np.zeros(2))}
    save_array_checkpoint(str(tmp_path), 10, tree)
    save_array_checkpoint(str(tmp_path), 20, tree)
    restored, step = load_array_checkpoint(str(tmp_path), tree)
    assert step == 20
    np.testing.assert_allclose(restored["a"], tree["a"])
    # corrupt the latest; loader must fall back to step 10
    latest = sorted(p for p in os.listdir(tmp_path)
                    if p.endswith(".npz"))[-1]
    with open(os.path.join(tmp_path, latest), "r+b") as f:
        f.seek(40)
        f.write(b"\xde\xad\xbe\xef")
    restored, step = load_array_checkpoint(str(tmp_path), tree)
    assert step == 10


def test_gram_driver_kron_precond_matches_jacobi():
    """The full distributed kron path (cached factors: per-pair,
    per-axis gram-tile, and segmented retirement) must reproduce the
    Jacobi driver's Gram matrix — the preconditioner only changes the
    solve trajectory (DESIGN.md §9)."""
    import jax.numpy as jnp
    ds = _dataset(6)
    mesh = _mesh()
    base = dict(ds=ds, mesh=mesh, vertex_kernel=VK, edge_kernel=EK,
                method="pallas_sparse", tol=1e-8)
    ref = GramDriver(**base).run()
    for extra in (dict(),                                   # per-pair
                  dict(gram_tile=True, tile_shape=(2, 2)),  # per-axis
                  dict(gram_tile=True, tile_shape=(2, 2),
                       segment_size=4)):                    # retirement
        K = GramDriver(**base, precond="kron", **extra).run()
        np.testing.assert_allclose(K, ref, rtol=1e-5, atol=1e-7)
    # factors are cached once per (graph, pad): a second run through
    # the same driver instance reuses them
    d = GramDriver(**base, precond="kron")
    d.run()
    cache = d._pack_cache
    assert cache is not None and len(cache._factors) > 0
    # bf16 pack streaming through the driver: same Gram at bf16
    # resolution, and the cached pack buffers really are bfloat16
    db = GramDriver(**base, precond="kron", pack_dtype=jnp.bfloat16)
    Kb = db.run()
    np.testing.assert_allclose(Kb, ref, rtol=3e-2, atol=1e-3)
    entry = next(iter(db._pack_cache._packs.values()))
    assert entry["values_adj"].dtype == jnp.bfloat16


def test_gram_driver_kron_grad_matches_jacobi():
    """run_with_grad under precond='kron' (adjoint reuses the cached
    factors via precond_factors/trust_pack_weights) matches Jacobi's
    gradient Gram blocks."""
    ds = _dataset(5)
    mesh = _mesh()
    base = dict(ds=ds, mesh=mesh, vertex_kernel=VK, edge_kernel=EK,
                method="pallas_sparse", tol=1e-10)
    Kj, Gj = GramDriver(**base).run_with_grad()
    Kk, Gk = GramDriver(**base, precond="kron",
                        gram_tile=True,
                        tile_shape=(2, 2)).run_with_grad()
    np.testing.assert_allclose(Kk, Kj, rtol=1e-5, atol=1e-7)
    assert sorted(Gk) == sorted(Gj)
    for key in Gj:
        np.testing.assert_allclose(Gk[key], Gj[key], rtol=1e-3,
                                   atol=1e-6)


def test_gram_tile_vmem_bytes_tracks_pack_dtype():
    """The Gram-tile VMEM estimator must cost packs at their stored
    itemsize — bf16 packs halve the operand share, which is what lets
    larger tiles stay on the single-launch kernel."""
    import jax.numpy as jnp
    from repro.kernels.ops import row_panel_packs_for_batch
    from repro.kernels.xmv_block_sparse import gram_tile_vmem_bytes
    from repro.core import batch_from_graphs
    gs = [g for g in make_drugbank_like_dataset(10, seed=7)
          if 6 <= g.n_nodes <= 24][:4]
    g1 = batch_from_graphs(gs[:2], pad_to=24)
    g2 = batch_from_graphs(gs[2:], pad_to=24)
    pf1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
    pf2 = row_panel_packs_for_batch(g2, edge_kernel=EK)
    pb1 = row_panel_packs_for_batch(g1, edge_kernel=EK,
                                    pack_dtype=jnp.bfloat16)
    pb2 = row_panel_packs_for_batch(g2, edge_kernel=EK,
                                    pack_dtype=jnp.bfloat16)
    for mxu in (False, True):
        f32 = gram_tile_vmem_bytes(pf1, pf2, mxu)
        bf16 = gram_tile_vmem_bytes(pb1, pb2, mxu)
        assert bf16 < f32
        # operand share halves exactly; the f32 P/diag/out share stays
        assert f32 - bf16 == (f32 - 8 * (24 * 24 + 2 * 8 * 24)) // 2
