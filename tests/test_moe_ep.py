"""Expert-parallel shard_map MoE path vs the single-device dense path.

Runs in a subprocess with 8 placeholder host devices (the parent pytest
process must keep seeing 1 CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_init, moe_layer

    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                    capacity_factor=4.0)
    d = 64
    p = moe_init(jax.random.key(0), d, cfg, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, d)), jnp.float32)
    out_d, aux_d = jax.jit(lambda p, x: moe_layer(p, x, cfg))(p, x)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        out_s, aux_s = jax.jit(lambda p, x: moe_layer(p, x, cfg))(p, x)
    err = float(jnp.max(jnp.abs(out_d - out_s)))
    assert err < 1e-4, err
    # aux uses per-shard statistics under EP; allow a statistical gap
    assert abs(float(aux_d) - float(aux_s)) / float(aux_d) < 0.10
    print("OK", err)
""") % (os.path.join(ROOT, "src"),)


@pytest.mark.slow
def test_ep_shard_map_matches_dense():
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
