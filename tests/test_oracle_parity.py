"""Property-based oracle parity: hypothesis-generated random labeled
graphs through EVERY mgk_adaptive backend — the four dispatch-table
cells plus the jnp reference paths and the adaptive entry itself — all
compared against the ``core/reference.mgk_direct`` dense LAPACK oracle
in ONE parameterized test. This subsumes the per-kernel parity checks
scattered through test_mgk/test_adaptive/test_row_panel (kept as fast
regression pins); new backends only need a row here.

Runs under the seeded hypothesis profile from conftest.py ("ci" =
derandomized) or the deterministic _hypothesis_compat grid when
hypothesis is not installed.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (CompactPolynomial, KroneckerDelta,
                        SquareExponential, batch_from_graphs, mgk_pairs)
from repro.core.mgk import mgk_adaptive, mgk_pairs_sparse
from repro.core.reference import mgk_direct
from repro.data import make_synthetic_dataset
from repro.kernels.ops import row_panel_packs_for_batch

VK = KroneckerDelta(0.5, n_labels=8)
SE = SquareExponential(1.0, rank=12)
CP = CompactPolynomial(1.0)

# every backend the adaptive table can dispatch to, plus the adaptive
# entry itself; (mode, edge_kernel, needs_packs)
BACKENDS = [
    ("full", SE), ("elementwise", SE), ("lowrank", SE),
    ("pallas", SE), ("pallas", CP),
    ("sparse_vpu", CP), ("sparse_vpu", SE), ("sparse_mxu", SE),
    ("adaptive", SE), ("adaptive", CP),
]


def _graph_pair(gtype: str, n: int, seed: int, q: float):
    gs = make_synthetic_dataset(gtype, n_graphs=2, n_nodes=n, seed=seed,
                                stop_prob=q)
    return gs[0], gs[1]


def _run_backend(mode, ek, g1b, g2b):
    if mode == "adaptive":
        return mgk_adaptive(g1b, g2b, VK, ek, tol=1e-12)
    if mode.startswith("sparse"):
        ek_pack = ek if mode == "sparse_mxu" else None
        p1 = row_panel_packs_for_batch(g1b, edge_kernel=ek_pack)
        p2 = row_panel_packs_for_batch(g2b, edge_kernel=ek_pack)
        return mgk_pairs_sparse(
            g1b, g2b, p1, p2, VK, ek,
            sparse_mode="mxu" if mode == "sparse_mxu" else "elementwise",
            tol=1e-12)
    return mgk_pairs(g1b, g2b, VK, ek, method=mode, tol=1e-12)


@pytest.mark.parametrize("mode,ek", BACKENDS,
                         ids=[f"{m}-{type(k).__name__}"
                              for m, k in BACKENDS])
@settings(max_examples=12, deadline=None)
@given(gtype=st.sampled_from(["nws", "ba"]),
       n=st.integers(8, 18),
       seed=st.integers(0, 4),
       q=st.floats(0.05, 0.4))
def test_backend_matches_direct_oracle(mode, ek, gtype, n, seed, q):
    g1, g2 = _graph_pair(gtype, n, seed, q)
    g1b = batch_from_graphs([g1])
    g2b = batch_from_graphs([g2])
    res = _run_backend(mode, ek, g1b, g2b)
    ref = mgk_direct(g1, g2, VK, ek)
    # rtol covers f32 accumulation + the SE expansion's rank-12
    # truncation on the MXU paths
    np.testing.assert_allclose(float(res.values[0]), ref, rtol=2e-3)
    assert bool(res.converged.all())


@settings(max_examples=8, deadline=None)
@given(n1=st.integers(8, 14), n2=st.integers(8, 14),
       seed=st.integers(0, 3))
def test_rectangular_pairs_match_oracle(n1, n2, seed):
    """Cross-bucket pairs (n != m, different pads) against the oracle —
    the Gram driver's off-diagonal blocks."""
    g1 = make_synthetic_dataset("nws", n_graphs=1, n_nodes=n1,
                                seed=seed, stop_prob=0.2)[0]
    g2 = make_synthetic_dataset("ba", n_graphs=1, n_nodes=n2,
                                seed=seed + 100, stop_prob=0.2)[0]
    res = mgk_pairs(batch_from_graphs([g1]), batch_from_graphs([g2]),
                    VK, SE, method="lowrank", tol=1e-12)
    np.testing.assert_allclose(float(res.values[0]),
                               mgk_direct(g1, g2, VK, SE), rtol=2e-3)
