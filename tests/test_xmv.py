"""XMV backends: all must agree with the full-materialization oracle
across shapes / dtypes / kernels (the per-kernel allclose requirement)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.base_kernels import CompactPolynomial, Constant, \
    SquareExponential
from repro.core.xmv import xmv_elementwise, xmv_full, xmv_lowrank
from repro.kernels.ref import xmv_ref
from repro.kernels.xmv_dense import xmv_dense
from repro.kernels.xmv_block_sparse import pack_graph, xmv_block_sparse

EDGE_KERNELS = [Constant(1.0), SquareExponential(0.8, rank=12),
                CompactPolynomial(1.0)]


def _pair(rng, n, m, density=1.0, dtype=np.float32):
    def mat(s):
        a = rng.random((s, s)).astype(dtype)
        if density < 1.0:
            a *= rng.random((s, s)) < density
        a = np.triu(a, 1)
        a = a + a.T
        e = rng.random((s, s)).astype(dtype) * (a != 0)
        return a, e
    A, E = mat(n)
    Ap, Ep = mat(m)
    P = rng.random((n, m)).astype(dtype)
    return A, E, Ap, Ep, P


@pytest.mark.parametrize("ek", EDGE_KERNELS, ids=lambda k: type(k).__name__)
@pytest.mark.parametrize("n,m", [(8, 8), (16, 24), (32, 16)])
def test_elementwise_matches_full(ek, n, m, rng):
    A, E, Ap, Ep, P = _pair(rng, n, m)
    y_full = xmv_full(A, E, Ap, Ep, P, ek)
    y_elem = xmv_elementwise(A, E, Ap, Ep, P, ek)
    np.testing.assert_allclose(y_elem, y_full, rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("ek", EDGE_KERNELS[:2],
                         ids=lambda k: type(k).__name__)
def test_lowrank_matches_full(ek, rng):
    A, E, Ap, Ep, P = _pair(rng, 16, 24)
    y_full = xmv_full(A, E, Ap, Ep, P, ek)
    y_lr = xmv_lowrank(A, E, Ap, Ep, P, ek)
    np.testing.assert_allclose(y_lr, y_full, rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,m", [(8, 8), (16, 16), (24, 40), (64, 32),
                                 (128, 128)])
def test_pallas_dense_sweep(n, m, dtype, rng):
    ek = SquareExponential(1.0, rank=10)
    A, E, Ap, Ep, P = _pair(rng, n, m)
    conv = lambda x: jnp.asarray(x, dtype)  # noqa: E731
    y = xmv_dense(conv(A), conv(E), conv(Ap), conv(Ep), conv(P), ek)
    y_ref = xmv_ref(jnp.asarray(A), jnp.asarray(E), jnp.asarray(Ap),
                    jnp.asarray(Ep), jnp.asarray(P), ek)
    tol = 2e-5 if dtype == np.float32 else 0.05
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,m,density", [(16, 16, 0.1), (32, 48, 0.05),
                                         (64, 64, 0.15), (40, 24, 0.3)])
def test_pallas_block_sparse_sweep(n, m, density, rng):
    ek = SquareExponential(1.0, rank=10)
    A, E, Ap, Ep, P = _pair(rng, n, m, density=density)
    y = xmv_block_sparse(pack_graph(A, E), pack_graph(Ap, Ep),
                         jnp.asarray(P), ek)
    y_ref = xmv_ref(jnp.asarray(A), jnp.asarray(E), jnp.asarray(Ap),
                    jnp.asarray(Ep), jnp.asarray(P), ek)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=1e-5)


def test_pallas_block_sparse_empty_graph(rng):
    ek = Constant(1.0)
    A = np.zeros((16, 16), np.float32)
    E = np.zeros_like(A)
    Ap, Ep, P = rng.random((24, 24)).astype(np.float32), None, None
    Ap = np.triu(Ap, 1) + np.triu(Ap, 1).T
    Ep = Ap.copy()
    P = rng.random((16, 24)).astype(np.float32)
    y = xmv_block_sparse(pack_graph(A, E), pack_graph(Ap, Ep),
                         jnp.asarray(P), ek)
    assert np.allclose(np.asarray(y), 0.0)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 24]), m=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 100))
def test_pallas_dense_property(n, m, seed):
    rng = np.random.default_rng(seed)
    ek = Constant(1.0)
    A, E, Ap, Ep, P = _pair(rng, n, m)
    y = xmv_dense(A, E, Ap, Ep, P, ek)
    y_ref = xmv_ref(jnp.asarray(A), jnp.asarray(E), jnp.asarray(Ap),
                    jnp.asarray(Ep), jnp.asarray(P), ek)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=1e-5)
