"""Adaptive backend dispatch (paper Sec. IV-B at the bucket level):
every route must give the same kernel values."""
import numpy as np
import pytest

from repro.core import (CompactPolynomial, KroneckerDelta,
                        SquareExponential, batch_from_graphs, mgk_pairs)
from repro.core.mgk import mgk_adaptive, tile_density
from repro.data import make_drugbank_like_dataset, make_synthetic_dataset

VK = KroneckerDelta(0.5, n_labels=8)


def test_density_statistic_orders_datasets():
    sparse = [g for g in make_drugbank_like_dataset(8, seed=1)
              if g.n_nodes >= 24][:2]
    dense = make_synthetic_dataset("ba", n_graphs=2, n_nodes=48, seed=0)
    d_sparse = tile_density(batch_from_graphs(sparse, pad_to=64))
    d_dense = tile_density(batch_from_graphs(dense, pad_to=48))
    assert d_sparse < d_dense


@pytest.mark.parametrize("ek", [SquareExponential(1.0, rank=12),
                                CompactPolynomial(1.0)],
                         ids=["expandable", "elementwise-only"])
def test_adaptive_matches_reference(ek):
    gs = [g for g in make_drugbank_like_dataset(14, seed=4)
          if 8 <= g.n_nodes <= 48][:4]
    a = batch_from_graphs(gs[:2], pad_to=48)
    b = batch_from_graphs(gs[2:], pad_to=48)
    res = mgk_adaptive(a, b, VK, ek, tol=1e-10)
    ref = mgk_pairs(a, b, VK, ek, method="full", tol=1e-10)
    np.testing.assert_allclose(np.asarray(res.values),
                               np.asarray(ref.values), rtol=1e-4)
