"""End-to-end launchers: training (with checkpoint resume) and serving."""
import numpy as np
import jax

from repro.configs import ARCHS
from repro.launch.serve import Request, ServeLoop
from repro.launch.train import TrainRun, run_training
from repro.models.model import init_params


def test_training_loss_decreases_and_resumes(tmp_path):
    cfg = ARCHS["qwen3-0.6b"].reduced()
    run = TrainRun(cfg=cfg, steps=12, batch=4, seq=32, lr=1e-3,
                   ckpt_dir=str(tmp_path), ckpt_every=6, log_every=4,
                   warmup_steps=0)
    _, losses = run_training(run)
    assert losses[-1][1] < losses[0][1]
    # resume from checkpoint: extend to 18 steps, must start at 12
    run2 = TrainRun(cfg=cfg, steps=18, batch=4, seq=32, lr=1e-3,
                    ckpt_dir=str(tmp_path), ckpt_every=6, log_every=4,
                    warmup_steps=0)
    _, losses2 = run_training(run2)
    assert losses2[0][0] >= 12            # resumed, not restarted


def test_serve_loop_completes_requests():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(
                        np.int32),
                    max_new=5)
            for i in range(6)]
    loop = ServeLoop(cfg, params, slots=3, s_max=32)
    done = loop.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) == 5 for r in done)
