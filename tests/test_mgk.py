"""End-to-end marginalized graph kernel: against two independent oracles,
plus the paper's structural properties (symmetry, permutation invariance,
PSD Gram, small-stopping-probability convergence, reordering invariance).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (KroneckerDelta, SquareExponential, batch_from_graphs,
                        mgk_pairs, pbr_order, rcm_order)
from repro.core.mgk import mgk_pairs_sparse
from repro.core.reference import mgk_direct, mgk_walk_sum
from repro.data import make_drugbank_like_dataset, make_synthetic_dataset
from repro.kernels.ops import packs_for_batch

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)


def _graphs(n=6, nodes=14, seed=0, stop=0.1):
    return make_synthetic_dataset("nws", n_graphs=n, n_nodes=nodes,
                                  seed=seed, stop_prob=stop)


@pytest.mark.parametrize("method", ["full", "elementwise", "lowrank",
                                    "pallas"])
def test_matches_direct_oracle(method):
    gs = _graphs(4)
    g1 = batch_from_graphs(gs[:2], pad_to=16)
    g2 = batch_from_graphs(gs[2:], pad_to=16)
    res = mgk_pairs(g1, g2, VK, EK, method=method, tol=1e-12)
    ref = [mgk_direct(gs[i], gs[2 + i], VK, EK) for i in range(2)]
    np.testing.assert_allclose(np.asarray(res.values), ref, rtol=1e-4)
    assert bool(res.converged.all())


def test_matches_walk_sum_definition():
    """Validates the linear-algebra reformulation (paper Appendix A)
    against the kernel's random-walk DEFINITION."""
    gs = _graphs(2, nodes=10, stop=0.3)
    g1 = batch_from_graphs(gs[:1], pad_to=16)
    g2 = batch_from_graphs(gs[1:], pad_to=16)
    res = mgk_pairs(g1, g2, VK, EK, method="full", tol=1e-12)
    ws = mgk_walk_sum(gs[0], gs[1], VK, EK, max_len=500)
    np.testing.assert_allclose(float(res.values[0]), ws, rtol=1e-4)


def test_symmetry():
    gs = _graphs(4)
    a = batch_from_graphs(gs[:2], pad_to=16)
    b = batch_from_graphs(gs[2:], pad_to=16)
    k_ab = mgk_pairs(a, b, VK, EK, tol=1e-12).values
    k_ba = mgk_pairs(b, a, VK, EK, tol=1e-12).values
    np.testing.assert_allclose(np.asarray(k_ab), np.asarray(k_ba),
                               rtol=1e-5)


def test_permutation_invariance(rng):
    gs = _graphs(2, nodes=12)
    perm = rng.permutation(12)
    gp = gs[0].permuted(perm)
    a = batch_from_graphs([gs[0], gp], pad_to=16)
    b = batch_from_graphs([gs[1], gs[1]], pad_to=16)
    res = mgk_pairs(a, b, VK, EK, tol=1e-12)
    np.testing.assert_allclose(float(res.values[0]), float(res.values[1]),
                               rtol=1e-4)


@pytest.mark.parametrize("order_fn", [rcm_order, pbr_order])
def test_reordering_invariance(order_fn):
    """Reordering is a performance transform — kernel values must not
    change (paper Sec. IV-A)."""
    gs = make_drugbank_like_dataset(6, seed=3)
    gs = [g for g in gs if g.n_nodes >= 8][:2]
    g = gs[0]
    p = order_fn(g.adjacency)
    a = batch_from_graphs([g, g.permuted(p)], pad_to=None)
    b = batch_from_graphs([gs[1], gs[1]], pad_to=None)
    res = mgk_pairs(a, b, VK, EK, tol=1e-12)
    np.testing.assert_allclose(float(res.values[0]), float(res.values[1]),
                               rtol=1e-4)


def test_small_stopping_probability_converges():
    """The paper highlights convergence at stopping probabilities as small
    as 0.0005 where CPU baselines fail."""
    gs = make_synthetic_dataset("nws", n_graphs=2, n_nodes=16, seed=1,
                                stop_prob=0.0005)
    a = batch_from_graphs(gs[:1])
    b = batch_from_graphs(gs[1:])
    res = mgk_pairs(a, b, VK, EK, tol=1e-10, max_iter=2000)
    assert bool(res.converged.all())
    ref = mgk_direct(gs[0], gs[1], VK, EK)
    np.testing.assert_allclose(float(res.values[0]), ref, rtol=1e-3)


def test_gram_matrix_psd():
    gs = _graphs(8, nodes=12)
    n = len(gs)
    K = np.zeros((n, n))
    batch_a, batch_b, idx = [], [], []
    for i in range(n):
        for j in range(i, n):
            batch_a.append(gs[i])
            batch_b.append(gs[j])
            idx.append((i, j))
    a = batch_from_graphs(batch_a, pad_to=16)
    b = batch_from_graphs(batch_b, pad_to=16)
    vals = np.asarray(mgk_pairs(a, b, VK, EK, tol=1e-10).values)
    for (i, j), v in zip(idx, vals):
        K[i, j] = K[j, i] = v
    w = np.linalg.eigvalsh(K)
    assert w.min() > -1e-6 * abs(w.max())


def test_sparse_path_matches_dense():
    gs = make_drugbank_like_dataset(8, seed=5)
    gs = [g for g in gs if g.n_nodes >= 6][:4]
    a = batch_from_graphs(gs[:2], pad_to=64)
    b = batch_from_graphs(gs[2:], pad_to=64)
    packs_a = packs_for_batch(a)
    packs_b = packs_for_batch(b)
    rs = mgk_pairs_sparse(a, b, packs_a, packs_b, VK, EK, tol=1e-12)
    rd = mgk_pairs(a, b, VK, EK, method="full", tol=1e-12)
    np.testing.assert_allclose(np.asarray(rs.values),
                               np.asarray(rd.values), rtol=1e-4)


def test_nodal_similarity_shape():
    gs = _graphs(2, nodes=10)
    a = batch_from_graphs(gs[:1], pad_to=16)
    b = batch_from_graphs(gs[1:], pad_to=16)
    res = mgk_pairs(a, b, VK, EK, return_nodal=True)
    assert res.nodal.shape == (1, 16, 16)
    # kernel value equals p^T-weighted nodal sum
    px = np.asarray(a.start_prob[0])[:, None] * \
        np.asarray(b.start_prob[0])[None, :]
    np.testing.assert_allclose(float((px * np.asarray(res.nodal[0])).sum()),
                               float(res.values[0]), rtol=1e-5)
