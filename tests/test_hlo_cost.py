"""The loop-trip-corrected HLO cost model (analysis/hlo_cost.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import analyze_hlo


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(c.as_text())


def test_matmul_flops_and_bytes():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    hc = _cost(lambda x, y: x @ y, a, a)
    assert abs(hc.flops - 2 * 256 ** 3) / (2 * 256 ** 3) < 0.05
    expect_bytes = 3 * 256 * 256 * 4
    assert abs(hc.hbm_bytes - expect_bytes) / expect_bytes < 0.5


def test_scan_trip_count_multiplies():
    def f(x, w):
        def body(c, wi):
            return c @ wi, ()
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    hc = _cost(f, x, w)
    expect = 12 * 2 * 128 ** 3
    assert abs(hc.flops - expect) / expect < 0.05
    assert hc.n_while == 1 and hc.unknown_trip_loops == 0
    # weights streamed once: ~12 slices of 64KB each, not 12x full stack
    assert hc.hbm_bytes < 4 * 12 * 128 * 128 * 4 * 3


def test_nested_scan_multiplies_transitively():
    def f(x, w):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, ()
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, ()
        y, _ = jax.lax.scan(outer, x, w)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 4, 64, 64), jnp.float32)
    hc = _cost(f, x, w)
    expect = 20 * 2 * 64 ** 3
    assert abs(hc.flops - expect) / expect < 0.1


def test_collectives_counted_with_groups():
    import os
    # collectives need a multi-device mesh; emulate with psum over 1 dev
    hc = _cost(lambda x: jnp.sum(x ** 2), jax.ShapeDtypeStruct(
        (128,), jnp.float32))
    assert hc.total_link_bytes == 0.0


def test_dus_counts_update_not_buffer():
    def f(buf, x):
        return jax.lax.dynamic_update_slice(buf, x, (0, 0))
    buf = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    # donated buffer -> true in-place update; traffic ~ 2x the update row,
    # NOT the 4 MB buffer (the KV-cache decode pattern)
    c = jax.jit(f, donate_argnums=(0,)).lower(buf, x).compile()
    hc = analyze_hlo(c.as_text())
    assert hc.hbm_bytes < 4096 * 256 * 4 * 0.1
    # without donation a defensive copy of the buffer is real traffic
    hc2 = _cost(f, buf, x)
    assert hc2.hbm_bytes >= 4096 * 256 * 4
