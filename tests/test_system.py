"""End-to-end behaviour tests for the paper's system: the complete
pipeline from raw graphs to a normalized Gram matrix, exercising
reordering, bucketing, scheduling, sharded pair-solves and
checkpointing in one pass — plus the multi-pod dry-run as a subprocess
(the container's single CPU only carries 512 placeholder devices in a
dedicated process)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
from jax.sharding import Mesh

from repro.core import (KroneckerDelta, SquareExponential, best_order,
                        batch_from_graphs, mgk_pairs)
from repro.data import bucket_graphs, make_drugbank_like_dataset
from repro.distributed import ChunkStore, GramDriver

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_full_pipeline_drugbank_like(tmp_path):
    graphs = [g for g in make_drugbank_like_dataset(16, seed=11)
              if g.n_nodes >= 4][:12]
    # production preprocessing: reorder each graph for tile density
    reordered = []
    for g in graphs:
        p, _, _ = best_order(g.adjacency)
        reordered.append(g.permuted(p))
    ds = bucket_graphs(reordered, max_buckets=3)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    drv = GramDriver(ds, mesh, KroneckerDelta(0.5, 8),
                     SquareExponential(1.0, rank=10),
                     store=ChunkStore(str(tmp_path)), pairs_per_block=16)
    K = drv.run()
    assert K.shape == (12, 12)
    assert not np.isnan(K).any()
    assert np.allclose(np.diag(K), 1.0, atol=1e-5)
    assert np.linalg.eigvalsh(K).min() > -1e-6
    # reordering must not change values: compare one pair against the
    # un-reordered graphs directly
    vk, ek = KroneckerDelta(0.5, 8), SquareExponential(1.0, rank=10)
    a = batch_from_graphs([graphs[0]], pad_to=None)
    b = batch_from_graphs([graphs[1]], pad_to=None)
    raw = mgk_pairs(a, b, vk, ek, tol=1e-10)
    d0 = mgk_pairs(a, a, vk, ek, tol=1e-10)
    d1 = mgk_pairs(b, b, vk, ek, tol=1e-10)
    expected = float(raw.values[0]) / np.sqrt(
        float(d0.values[0]) * float(d1.values[0]))
    np.testing.assert_allclose(K[0, 1], expected, rtol=1e-3)


@pytest.mark.slow
def test_multipod_dryrun_subprocess(tmp_path):
    """Lower+compile the paper's gram step on the 2x16x16 multi-pod mesh
    (512 placeholder devices) in a subprocess — the minimal live check of
    the multi-pod deliverable."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mgk-gram",
         "--shape", "gram_block", "--mesh", "multi", "--out",
         str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=540,
        cwd=str(tmp_path))
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(os.path.join(
        tmp_path, "mgk-gram__gram_block__multi__baseline.json")))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 512
    assert rec["mesh_shape"] == {"pod": 2, "data": 16, "model": 16}
