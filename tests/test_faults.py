"""Self-healing Gram builds under the deterministic fault harness:
campaign bitwise-identity, crash/restart, quarantine-and-recompute,
degradation-ladder escalation, journal robustness (DESIGN.md §10)."""
import json
import os
import tempfile

import numpy as np
import pytest
import jax
from jax.sharding import Mesh
from _hypothesis_compat import given, settings, st

from repro.core import KroneckerDelta, SquareExponential
from repro.data import bucket_graphs, make_drugbank_like_dataset
from repro.distributed import ChunkStore, FaultInjector, FaultPlan, \
    GramDriver, assemble_blocks, run_campaign
from repro.distributed.faults import _hash01

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=10)


def _dataset(n=8, seed=7):
    gs = [g for g in make_drugbank_like_dataset(n + 6, seed=seed)
          if g.n_nodes >= 4][:n]
    return bucket_graphs(gs, max_buckets=3)


def _mesh():
    return Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))


def _driver(ds, store, injector=None, **kw):
    kw.setdefault("method", "pallas_sparse")
    kw.setdefault("pairs_per_block", 8)
    return GramDriver(ds, _mesh(), VK, EK, store=store, faults=injector,
                      **kw)


def _journal_ops(root):
    ops = []
    with open(os.path.join(root, "manifest.jsonl")) as f:
        for line in f:
            if line.strip():
                ops.append(json.loads(line))
    return ops


def test_hash01_deterministic():
    a = _hash01(3, 17, "nan")
    assert a == _hash01(3, 17, "nan")
    assert 0.0 <= a < 1.0
    assert _hash01(3, 17, "nan") != _hash01(3, 18, "nan")
    assert _hash01(3, 17, "nan") != _hash01(3, 17, "cert")


def test_campaign_bitwise_identical(tmp_path):
    """The acceptance campaign: kill + corruption + truncation + matvec
    NaNs + forced certificate failure, all transient — the healed build
    must equal the fault-free build BIT FOR BIT, with the interventions
    accounted for in health/manifest."""
    ds = _dataset(8)
    K_clean = _driver(ds, ChunkStore(str(tmp_path / "clean")),
                      precond="kron").run()
    plan = FaultPlan(seed=3, kill_after_blocks=3, corrupt_fraction=0.3,
                     truncate_fraction=0.2, matvec_nan_fraction=0.5,
                     cert_fail_fraction=0.4)
    K_fault, report = run_campaign(
        lambda inj: _driver(ds, ChunkStore(str(tmp_path / "faulty")),
                            inj, precond="kron"), plan)
    assert np.array_equal(K_clean, K_fault)
    assert not np.isnan(K_fault).any()
    assert report["restarts"] >= 1
    assert report["injections"].get("matvec_nan", 0) > 0
    assert report["injections"].get("kill", 0) == 1
    # every solve-time injection left a recovery trail in the manifest
    store = ChunkStore(str(tmp_path / "faulty"))
    recovered = {bid for bid in store.done_blocks()
                 if "recovery" in (store.block_entry(bid) or {})}
    assert recovered, "no recovery records despite injections"


def test_crash_restart_recomputes_only_missing(tmp_path):
    """Kill after K blocks, restart against the same store: finished
    blocks must NOT recompute (exactly one manifest add per block) and
    the final Gram equals an uninterrupted run's."""
    ds = _dataset(6)
    K_ref = _driver(ds, ChunkStore(str(tmp_path / "ref"))).run()
    plan = FaultPlan(seed=0, kill_after_blocks=2)
    K, report = run_campaign(
        lambda inj: _driver(ds, ChunkStore(str(tmp_path / "killed")),
                            inj), plan)
    assert report["restarts"] == 1
    assert np.array_equal(K_ref, K)
    adds = [op["block"] for op in _journal_ops(str(tmp_path / "killed"))
            if op.get("op") == "add"]
    assert sorted(adds) == sorted(set(adds)), \
        "a finished block was recomputed after restart"


def test_corrupt_chunk_quarantined_and_recomputed(tmp_path):
    """Bit rot after a completed run: the next run detects the CRC
    mismatch on restore, journals a quarantine tombstone, recomputes
    just that block, and lands on the identical Gram."""
    ds = _dataset(6)
    store_dir = str(tmp_path / "store")
    K_ref = _driver(ds, ChunkStore(store_dir)).run()
    path = ChunkStore(store_dir).block_path(0)
    data = bytearray(open(path, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(path, "wb").write(bytes(data))
    K = _driver(ds, ChunkStore(store_dir)).run()
    assert np.array_equal(K_ref, K)
    ops = _journal_ops(store_dir)
    assert any(op.get("op") == "quarantine" and op["block"] == 0
               for op in ops)
    # the recompute re-added the block with a fresh CRC: loads clean now
    assert ChunkStore(store_dir).load_block(0) is not None


def test_persistent_cert_failure_escalates_to_jacobi(tmp_path):
    """A PERSISTENT kron-certificate failure can't be healed by
    retrying — the ladder must escalate to the jacobi rung, whose solve
    is configuration-identical to a jacobi-from-the-start driver, so
    the healed Gram matches that driver's bit for bit."""
    ds = _dataset(6)
    K_jacobi = _driver(ds, ChunkStore(str(tmp_path / "jac")),
                       precond="jacobi").run()
    plan = FaultPlan(seed=5, cert_fail_fraction=1.0,
                     transient_attempts=10**9)
    drv = _driver(ds, ChunkStore(str(tmp_path / "healed")),
                  FaultInjector(plan), precond="kron",
                  max_block_retries=0)
    K = drv.run()
    assert drv.health["escalations"] > 0
    assert not np.isnan(K).any()
    assert np.array_equal(K_jacobi, K)


def test_poison_pair_quarantined_and_accounted(tmp_path):
    """A pair that fails every rung INCLUDING the reference oracle is
    quarantined: excluded from the Gram (NaN hole, loudly warned),
    listed in driver health and in the block's manifest record — never
    a silent NaN."""
    ds = _dataset(5)
    plan = FaultPlan(seed=1, matvec_nan_fraction=1.0,
                     transient_attempts=10**9)
    drv = _driver(ds, ChunkStore(str(tmp_path / "s")),
                  FaultInjector(plan), max_block_retries=0,
                  normalize=False)
    real_ref = drv._reference_block

    def poisoned_ref(block):
        out = real_ref(block)
        if block.block_id == 0:
            out["values"][0] = np.nan   # oracle fails too -> quarantine
        return out

    drv._reference_block = poisoned_ref
    with pytest.warns(UserWarning, match="NaN hole"):
        K = drv.run()
    qpairs = drv.health["quarantined_pairs"]
    assert len(qpairs) == 1
    (i, j), = [tuple(p) for p in qpairs]
    holes = {tuple(int(v) for v in h) for h in np.argwhere(np.isnan(K))}
    assert holes == {(i, j), (j, i)}   # sets dedupe the i == j case
    entry = ChunkStore(str(tmp_path / "s")).block_entry(0)
    assert [tuple(p) for p in entry["quarantined_pairs"]] == [(i, j)]


def test_nonconvergence_surfaced(tmp_path):
    """Pairs that hit max_iter without reaching tol are counted per
    bucket in driver health and journaled — not recorded
    indistinguishably from converged ones, and NOT escalated (slow is
    not sick)."""
    ds = _dataset(6)
    drv = _driver(ds, ChunkStore(str(tmp_path / "s")), max_iter=2,
                  tol=1e-12)
    K = drv.run()
    assert np.isfinite(K).all()
    assert drv.health["nonconverged_by_bucket"]
    assert drv.health["escalations"] == 0
    notes = ChunkStore(str(tmp_path / "s")).notes()
    assert any(n.get("kind") == "nonconvergence" and n["buckets"]
               for n in notes)


def test_assemble_blocks_strict():
    blk = {"rows": np.array([0, 0]), "cols": np.array([0, 1]),
           "values": np.array([1.0, 2.0])}
    with pytest.raises(ValueError, match="NaN hole"):
        assemble_blocks([blk], 3, "values")
    with pytest.warns(UserWarning, match="NaN hole"):
        M = assemble_blocks([blk], 3, "values", strict=False)
    assert np.isnan(M[2, 2]) and M[0, 1] == 2.0 and M[1, 0] == 2.0


def test_store_reaps_stale_tmps(tmp_path):
    stray = tmp_path / "block_00000000.npz.tmp.999.deadbeef"
    stray.write_bytes(b"junk from a crashed writer")
    ChunkStore(str(tmp_path))
    assert not stray.exists()


def test_atomic_write_cleans_tmp_on_failure(tmp_path, monkeypatch):
    from repro.distributed.checkpoint import _atomic_write
    monkeypatch.setattr(os, "rename",
                        lambda a, b: (_ for _ in ()).throw(OSError("x")))
    with pytest.raises(OSError):
        _atomic_write(str(tmp_path / "f.bin"), b"data")
    monkeypatch.undo()
    assert list(tmp_path.iterdir()) == []


def test_journal_compaction_preserves_state(tmp_path):
    store = ChunkStore(str(tmp_path))
    one = dict(rows=np.array([0]), cols=np.array([1]),
               values=np.array([1.0]), iterations=np.array([3]))
    for bid in range(4):
        store.save_block(bid, **one)
    for _ in range(3):       # churn: quarantine/recompute cycles
        store.quarantine_block(2, "test churn")
        store.save_block(2, **one)
    before = (store.done_blocks(), store.quarantined_blocks())
    dropped = store.compact_manifest()
    assert dropped > 0
    fresh = ChunkStore(str(tmp_path))
    assert (fresh.done_blocks(), fresh.quarantined_blocks()) == before
    assert fresh.load_block(2) is not None


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6), cut=st.integers(0, 600))
def test_journal_roundtrip_torn_writes(seed, cut):
    """Property: whatever op sequence was journaled, a crash truncating
    the journal at ANY byte leaves a store that (a) opens without error
    and (b) folds exactly the complete-line prefix under the documented
    semantics (first add wins; quarantine retires; later add readds)."""
    rng = np.random.default_rng(seed)
    one = dict(rows=np.array([0]), cols=np.array([1]),
               values=np.array([1.0]), iterations=np.array([2]))
    with tempfile.TemporaryDirectory() as d:
        store = ChunkStore(d)
        for k in range(12):
            op = int(rng.integers(0, 3))
            bid = int(rng.integers(0, 5))
            if op == 0:
                store.save_block(bid, **one)
            elif op == 1:
                store.quarantine_block(bid, "torn-test")
            else:
                store.note(kind="torn-test", k=k)
        with open(os.path.join(d, "manifest.jsonl"), "rb") as f:
            data = f.read()
        torn = data[:min(cut, len(data))]
        # independent model of the fold over the complete-line prefix
        complete = torn[:torn.rfind(b"\n") + 1] if b"\n" in torn else b""
        done, quar, notes = {}, set(), 0
        for line in complete.decode().splitlines():
            rec = json.loads(line)
            if rec["op"] == "add":
                if rec["block"] not in done:
                    done[rec["block"]] = rec["crc"]
                    quar.discard(rec["block"])
            elif rec["op"] == "quarantine":
                done.pop(rec["block"], None)
                quar.add(rec["block"])
            else:
                notes += 1
        with tempfile.TemporaryDirectory() as d2:
            with open(os.path.join(d2, "manifest.jsonl"), "wb") as f:
                f.write(torn)
            reopened = ChunkStore(d2)
            assert reopened.done_blocks() == set(done)
            assert set(reopened.quarantined_blocks()) == quar
            assert len(reopened.notes()) == notes
