"""Reordering: validity, tile-count reduction (paper Fig. 7), and the
solver's invariance to reordering."""
import numpy as np
import pytest

from repro.core.octile import count_nonempty_tiles
from repro.core.reorder import best_order, morton_order, pbr_order, \
    rcm_order
from repro.data.molecules import pdb_like_graph
from repro.data.synthetic import newman_watts_strogatz


def _banded(rng, n, bw):
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        for j in range(max(0, i - bw), min(n, i + bw + 1)):
            if i != j and rng.random() < 0.6:
                a[i, j] = a[j, i] = 1.0
    return a


@pytest.mark.parametrize("method", [rcm_order, pbr_order])
def test_returns_permutation(method, rng):
    g = newman_watts_strogatz(50, rng=rng, labeled=False)
    p = method(g.adjacency)
    assert sorted(p.tolist()) == list(range(50))


def test_morton_is_permutation(rng):
    coords = rng.random((64, 3))
    p = morton_order(coords)
    assert sorted(p.tolist()) == list(range(64))


def test_rcm_reduces_bandwidth_of_shuffled_band(rng):
    a = _banded(rng, 96, 3)
    perm = rng.permutation(96)
    shuffled = a[np.ix_(perm, perm)]
    p = rcm_order(shuffled)
    re = shuffled[np.ix_(p, p)]
    def bandwidth(m):
        i, j = np.nonzero(m)
        return np.abs(i - j).max() if len(i) else 0
    assert bandwidth(re) < bandwidth(shuffled)


def test_pbr_reduces_tiles_on_shuffled_protein(rng):
    g, _ = pdb_like_graph(120, rng=rng)
    perm = rng.permutation(120)
    shuffled = g.adjacency[np.ix_(perm, perm)]
    base = count_nonempty_tiles(shuffled)
    p = pbr_order(shuffled)
    after = count_nonempty_tiles(shuffled[np.ix_(p, p)])
    # paper Fig. 7: PBR beats a destroyed natural order decisively
    assert after < base


def test_morton_reduces_tiles_for_spatial_graph(rng):
    g, coords = pdb_like_graph(150, rng=rng)
    perm = rng.permutation(150)
    shuffled = g.adjacency[np.ix_(perm, perm)]
    p = morton_order(coords[perm])
    after = count_nonempty_tiles(shuffled[np.ix_(p, p)])
    assert after < count_nonempty_tiles(shuffled)


def test_best_order_never_worse_than_natural(rng):
    g, coords = pdb_like_graph(100, rng=rng)
    _, name, score = best_order(g.adjacency, coords=coords)
    assert score <= count_nonempty_tiles(g.adjacency)


# -- property-based invariants (seeded hypothesis profile, conftest) -------

from _hypothesis_compat import given, settings, st  # noqa: E402


def _random_graph(n: int, density: float, seed: int) -> np.ndarray:
    r = np.random.default_rng(seed)
    a = (r.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    a = a + a.T
    return a


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 60), density=st.floats(0.02, 0.4),
       seed=st.integers(0, 10))
def test_orders_always_valid_permutations(n, density, seed):
    """rcm_order / pbr_order must return a bijection on [0, n) for ANY
    graph — disconnected, empty, dense — and morton_order for any point
    cloud; a broken permutation silently corrupts every pack downstream."""
    a = _random_graph(n, density, seed)
    want = list(range(n))
    assert sorted(rcm_order(a).tolist()) == want
    assert sorted(pbr_order(a).tolist()) == want
    coords = np.random.default_rng(seed).random((n, 3))
    assert sorted(morton_order(coords).tolist()) == want


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 64), density=st.floats(0.02, 0.3),
       seed=st.integers(0, 10))
def test_pbr_never_worse_than_identity(n, density, seed):
    """PBR keeps the identity permutation as a zeroth candidate, so its
    tile count can never exceed the natural ordering's (the invariant
    that makes it safe to apply unconditionally in the pipeline)."""
    a = _random_graph(n, density, seed)
    base = count_nonempty_tiles(a)
    p = pbr_order(a)
    assert count_nonempty_tiles(a[np.ix_(p, p)]) <= base


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 48), seed=st.integers(0, 6))
def test_pbr_valid_on_edgeless_and_complete(n, seed):
    """Degenerate extremes: no edges (nothing to cut) and the complete
    graph (nothing to gain) must both yield valid permutations with
    tile count equal to the identity's."""
    for a in (np.zeros((n, n), np.float32),
              (np.ones((n, n)) - np.eye(n)).astype(np.float32)):
        p = pbr_order(a)
        assert sorted(p.tolist()) == list(range(n))
        assert count_nonempty_tiles(a[np.ix_(p, p)]) == \
            count_nonempty_tiles(a)
