import os
import sys

# tests must see the real single CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
