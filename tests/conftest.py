import os
import sys

# tests must see the real single CPU device (the dry-run sets its own
# XLA_FLAGS in a separate process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# Hypothesis profiles (no-op on minimal installs, where the
# _hypothesis_compat shim runs a fixed grid instead): "ci" is fully
# deterministic — derandomized, fixed seed, modest example count — so CI
# failures reproduce; "dev" explores more. Select with
# HYPOTHESIS_PROFILE=ci (the workflow does) or fall back to "dev".
try:  # noqa: SIM105
    from hypothesis import settings as _hsettings

    _hsettings.register_profile("ci", max_examples=25, derandomize=True,
                                deadline=None, print_blob=True)
    _hsettings.register_profile("dev", max_examples=100, deadline=None)
    _hsettings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Release compiled executables after each test module.

    The whole tier-1 suite runs in ONE process; every module compiles
    its own large family of jitted solves (distinct closures, so nothing
    is shared across modules anyway) and the CPU client keeps all of
    them alive. Past a few hundred executables the accumulated JIT code
    can crash a later XLA compile outright, so bound the live set to one
    module's worth.
    """
    yield
    import jax

    jax.clear_caches()
