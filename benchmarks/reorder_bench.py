"""Paper Figs. 6-7 analog: non-empty octile counts under natural / RCM /
PBR (/ Morton) orderings on the four benchmark datasets, plus reordering
wall time (the paper's 'reordering overhead' argument)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.octile import count_nonempty_tiles
from repro.core.reorder import morton_order, pbr_order, rcm_order
from repro.data import (make_drugbank_like_dataset, make_pdb_like_dataset,
                        make_synthetic_dataset)
from .common import row


def _datasets():
    nws = [g.adjacency for g in make_synthetic_dataset(
        "nws", n_graphs=8, n_nodes=96, seed=0)]
    ba = [g.adjacency for g in make_synthetic_dataset(
        "ba", n_graphs=8, n_nodes=96, seed=0)]
    pdb, coords = make_pdb_like_dataset(n_graphs=6, min_atoms=80,
                                        max_atoms=160, seed=0)
    drugs = [g.adjacency for g in make_drugbank_like_dataset(20, seed=0)
             if g.n_nodes >= 24]
    return {"nws": ([a for a in nws], None),
            "ba": ([a for a in ba], None),
            "pdb_like": ([g.adjacency for g in pdb], coords),
            "drugbank_like": (drugs, None)}


def run() -> list[str]:
    out = []
    for name, (mats, coords) in _datasets().items():
        # shuffle first: the paper's point is recovering locality when the
        # natural order is unavailable
        rng = np.random.default_rng(1)
        totals = {"natural": 0, "shuffled": 0, "rcm": 0, "pbr": 0}
        times = {"rcm": 0.0, "pbr": 0.0}
        if coords is not None:
            totals["morton"] = 0
            times["morton"] = 0.0
        for gi, a in enumerate(mats):
            n = a.shape[0]
            perm = rng.permutation(n)
            sh = a[np.ix_(perm, perm)]
            totals["natural"] += count_nonempty_tiles(a)
            totals["shuffled"] += count_nonempty_tiles(sh)
            for meth, fn in (("rcm", rcm_order), ("pbr", pbr_order)):
                t0 = time.perf_counter()
                p = fn(sh)
                times[meth] += time.perf_counter() - t0
                totals[meth] += count_nonempty_tiles(sh[np.ix_(p, p)])
            if coords is not None:
                t0 = time.perf_counter()
                p = morton_order(coords[gi][perm])
                times["morton"] += time.perf_counter() - t0
                totals["morton"] += count_nonempty_tiles(sh[np.ix_(p, p)])
        base = totals["shuffled"]
        for meth, tot in totals.items():
            us = times.get(meth, 0.0) * 1e6 / max(len(mats), 1)
            out.append(row(f"reorder_{name}_{meth}", us,
                           f"octiles={tot};reduction={base / max(tot, 1):.2f}x"))
    return out


if __name__ == "__main__":
    run()
