"""PR 5 perf tracking: PCG iteration counts at the source.

Emits ``BENCH_pcg.json`` with, per octile-density bucket (sparse /
medium / dense synthetic fixtures):

* iterations-to-tol (total, mean, max over the bucket) and pair-matvec
  evaluations for ``precond="jacobi"`` vs ``precond="kron"`` — the
  Kronecker-factored approximate inverse of ``core/precond.py``
  (DESIGN.md §9) attacks the iteration COUNT where PRs 1-4 attacked
  per-iteration cost. CI asserts kron reaches tol=1e-6 in ≥30% fewer
  iterations on the dense bucket, with identical solutions;
* end-to-end bucket solve wall-clock (product-system build + PCG to
  tol on the production row-panel MXU matvec) for both preconditioners
  — kron pays two small [n,n] @ X @ [m,m] matmuls per iteration to
  save whole matvecs, so wall-clock must be no worse anywhere and
  strictly better where matvecs dominate;
* bf16 pack streaming (§9.4): HBM bytes per matvec streamed by the
  pack value buffers at f32 vs ``pack_dtype=jnp.bfloat16`` (exactly
  2x) and the measured matvec parity error.

Numbers come from the CPU/interpret harness: absolute times are not
TPU times, but iteration counts are solver-exact and the bytes model
is arithmetic over buffer sizes.
"""
from __future__ import annotations

import json

import numpy as np

import jax.numpy as jnp

from repro.core.base_kernels import Constant, SquareExponential
from repro.core.graph import Graph, batch_from_graphs
from repro.core.mgk import mgk_pairs_sparse
from repro.kernels.ops import row_panel_packs_for_batch
from repro.kernels.xmv_block_sparse import xmv_row_panel_batched
from .common import row, time_fn

VK = Constant(1.0)
EK = SquareExponential(1.0, rank=12)

# (name, kind) buckets spanning the adaptive dispatch table's octile
# density range: molecule-like sparse graphs (band + ring structure,
# low octile occupancy) through erdos-renyi fixtures whose occupancy
# saturates — "dense" is the CI-asserted fixture
BUCKETS = (("sparse", "drugbank"), ("medium", "er:0.15"),
           ("dense", "er:0.40"))


def _bucket(B: int, n: int, kind: str, seed: int, q: float = 0.05):
    """Synthetic fixture bucket with the paper's small stopping
    probability (the near-critical regime where iteration counts hurt
    most). ``kind``: "drugbank" (molecule-like sparse) or "er:<p>"
    (erdos-renyi at edge probability p)."""
    import dataclasses
    rng = np.random.default_rng(seed)
    if kind == "drugbank":
        from repro.data import make_drugbank_like_dataset
        gs = []
        for s in range(seed, seed + 100):
            cand = make_drugbank_like_dataset(2 * B, seed=s)
            gs += [g for g in cand if 6 <= g.n_nodes <= n]
            if len(gs) >= 2 * B:
                break
        # pin the requested stopping probability (the generator has its
        # own default) so every bucket probes the same conditioning
        gs = [dataclasses.replace(
            g, stop_prob=np.full(g.n_nodes, q, np.float32))
            for g in gs[:2 * B]]
    else:
        p = float(kind.split(":")[1])
        gs = []
        for _ in range(2 * B):
            a = (rng.random((n, n)) < p).astype(np.float32)
            a = np.triu(a, 1)
            a = a + a.T
            e = rng.random((n, n)).astype(np.float32)
            e = (e + e.T) / 2 * (a != 0)
            v = rng.integers(0, 4, n).astype(np.float32)
            gs.append(Graph.create(a, e, v, stop_prob=q))
    pad = n + (-n) % 8
    return (batch_from_graphs(gs[:B], pad_to=pad),
            batch_from_graphs(gs[B:], pad_to=pad))


def _pack_bytes(pack) -> int:
    """HBM bytes of the value buffers a matvec streams (indices/counts
    excluded — they are SMEM scalar-prefetch traffic)."""
    total = 0
    for field in ("values_adj", "values_lab", "values_w", "values_grad"):
        arr = getattr(pack, field)
        if arr is not None:
            total += arr.nbytes
    return total


def run(out_path: str = "BENCH_pcg.json", B: int = 4, n: int = 32,
        iters: int = 3, tol: float = 1e-6, seed: int = 11) -> dict:
    report: dict = {"tol": tol, "pcg": [], "bf16": {}}

    for name, kind in BUCKETS:
        g1, g2 = _bucket(B, n, kind, seed)
        p1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
        p2 = row_panel_packs_for_batch(g2, edge_kernel=EK)

        def solve(precond):
            return mgk_pairs_sparse(g1, g2, p1, p2, VK, EK,
                                    sparse_mode="mxu", tol=tol,
                                    precond=precond)

        rj, rk = solve("jacobi"), solve("kron")
        ij = np.asarray(rj.iterations)
        ik = np.asarray(rk.iterations)
        assert bool(np.asarray(rj.converged).all())
        assert bool(np.asarray(rk.converged).all())
        vals_err = float(np.max(np.abs(
            (np.asarray(rk.values) - np.asarray(rj.values))
            / np.maximum(np.abs(np.asarray(rj.values)), 1e-30))))
        # end-to-end bucket solve wall clock, both arms (values output
        # forces the whole pipeline)
        us_j = time_fn(lambda: solve("jacobi").values.block_until_ready(),
                       iters=iters)
        us_k = time_fn(lambda: solve("kron").values.block_until_ready(),
                       iters=iters)
        entry = {
            "bucket": name, "kind": kind, "B": B, "n": n,
            "octile_density": None,   # filled below from pack stats
            "iters_jacobi_total": int(ij.sum()),
            "iters_kron_total": int(ik.sum()),
            "iters_jacobi_max": int(ij.max()),
            "iters_kron_max": int(ik.max()),
            "iter_reduction": 1.0 - ik.sum() / max(ij.sum(), 1),
            "matvec_pairs_jacobi": int(rj.matvec_pairs),
            "matvec_pairs_kron": int(rk.matvec_pairs),
            "us_solve_jacobi": us_j,
            "us_solve_kron": us_k,
            "wallclock_speedup": us_j / max(us_k, 1e-9),
            "values_max_rel_err": vals_err,
        }
        from repro.core.mgk import tile_density
        entry["octile_density"] = max(tile_density(g1), tile_density(g2))
        report["pcg"].append(entry)
        row(f"pcg_{name}_jacobi", us_j, f"iters={int(ij.sum())}")
        row(f"pcg_{name}_kron", us_k,
            f"iters={int(ik.sum())}"
            f",reduction={entry['iter_reduction']:.1%}"
            f",speedup={entry['wallclock_speedup']:.2f}x")

    # bf16 pack streaming: bytes per matvec + measured parity
    g1, g2 = _bucket(B, n, BUCKETS[1][1], seed)
    pf1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
    pf2 = row_panel_packs_for_batch(g2, edge_kernel=EK)
    pb1 = row_panel_packs_for_batch(g1, edge_kernel=EK,
                                    pack_dtype=jnp.bfloat16)
    pb2 = row_panel_packs_for_batch(g2, edge_kernel=EK,
                                    pack_dtype=jnp.bfloat16)
    rng = np.random.default_rng(seed)
    nn = g1.adjacency.shape[1]
    P = jnp.asarray(rng.random((B, nn, nn)).astype(np.float32))
    yf = xmv_row_panel_batched(pf1, pf2, P, EK, mode="mxu")
    yb = xmv_row_panel_batched(pb1, pb2, P, EK, mode="mxu")
    rel = float(np.max(np.abs(np.asarray(yf - yb)))
                / np.max(np.abs(np.asarray(yf))))
    bytes_f32 = _pack_bytes(pf1) + _pack_bytes(pf2)
    bytes_bf16 = _pack_bytes(pb1) + _pack_bytes(pb2)
    report["bf16"] = {
        "bytes_per_matvec_f32": bytes_f32,
        "bytes_per_matvec_bf16": bytes_bf16,
        "bytes_ratio": bytes_f32 / max(bytes_bf16, 1),
        "matvec_max_rel_err": rel,
    }
    row("pack_bytes_f32", float(bytes_f32), "per-matvec value buffers")
    row("pack_bytes_bf16", float(bytes_bf16),
        f"ratio={report['bf16']['bytes_ratio']:.2f}x,err={rel:.1e}")

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return report


if __name__ == "__main__":
    run()
