"""Paper Fig. 10 analog: this solver vs a GraKeL-style CPU baseline.

The baseline is what GraKeL/GraphKernels do for the random-walk family:
build the EXPLICIT nm x nm product system per pair and solve it with a
dense direct method on the CPU (numpy/LAPACK, single core — paper gives
GraphKernels 1 core, GraKeL 4). Ours is the batched on-the-fly CG solver
under XLA jit on the same CPU. On the target v5e the gap widens by the
accelerator factor; the derived column reports pairs/s for both.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import KroneckerDelta, SquareExponential, \
    batch_from_graphs, mgk_pairs
from repro.core.reference import mgk_direct
from repro.data import make_synthetic_dataset
from .common import row, time_fn

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)


def run(n_graphs: int = 12, n_nodes: int = 32) -> list[str]:
    gs = make_synthetic_dataset("nws", n_graphs=n_graphs, n_nodes=n_nodes,
                                seed=0)
    pairs = [(i, j) for i in range(n_graphs) for j in range(i, n_graphs)]

    # GraKeL-style explicit baseline (time a subset, extrapolate)
    sub = pairs[:12]
    t0 = time.perf_counter()
    for i, j in sub:
        mgk_direct(gs[i], gs[j], VK, EK)
    t_explicit = (time.perf_counter() - t0) / len(sub)

    # ours: batched, jitted, on-the-fly low-rank XMV
    A = batch_from_graphs([gs[i] for i, _ in pairs], pad_to=n_nodes)
    B = batch_from_graphs([gs[j] for _, j in pairs], pad_to=n_nodes)
    us_batch = time_fn(lambda a, b: mgk_pairs(a, b, VK, EK,
                                              method="lowrank",
                                              tol=1e-8).values,
                       A, B, iters=3)
    t_ours = us_batch / 1e6 / len(pairs)

    speedup = t_explicit / t_ours
    out = [
        row("packages_explicit_cpu_per_pair", t_explicit * 1e6,
            f"pairs_per_s={1 / t_explicit:.1f}"),
        row("packages_ours_per_pair", t_ours * 1e6,
            f"pairs_per_s={1 / t_ours:.1f};speedup={speedup:.1f}x"),
    ]
    return out


if __name__ == "__main__":
    run()
