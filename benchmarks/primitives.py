"""Paper Fig. 5 / Table I analog: XMV primitive comparison.

Wall-clock (CPU, XLA-jitted — relative ordering is the signal) of one
product-system matvec per backend, plus the Table-I analytic arithmetic
intensity derived for the TPU tilings. The CUDA primitives (shared tiling /
register blocking / tiling&blocking) map to our one Pallas tiling with
different tile parameters; the naive primitive materializes L_x exactly as
the paper's baseline does.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.base_kernels import SquareExponential
from repro.core.xmv import weighted_operands, xmv_elementwise, xmv_full, \
    xmv_lowrank_precomputed
from .common import row, time_fn

EK = SquareExponential(1.0, rank=12)


def _naive_setup(A, E, Ap, Ep):
    """Precompute L_x = (A (x) A') .* kappa(E (x) E') (the paper's naive
    baseline: O(n^2 m^2) storage, bandwidth-bound matvec)."""
    n, m = A.shape[0], Ap.shape[0]
    K = EK(E[:, :, None, None], Ep[None, None, :, :])
    W = A[:, :, None, None] * Ap[None, None, :, :] * K
    return W.transpose(0, 2, 1, 3).reshape(n * m, n * m)


def run(sizes=(32, 64, 96)) -> list[str]:
    rng = np.random.default_rng(0)
    out = []
    for n in sizes:
        A = rng.random((n, n), np.float32)
        E = rng.random((n, n), np.float32)
        P = rng.random((n, n), np.float32)
        Aj, Ej, Pj = map(jnp.asarray, (A, E, P))

        Lx = jax.jit(_naive_setup)(Aj, Ej, Aj, Ej)
        naive_mv = jax.jit(lambda L, p: L @ p)
        us = time_fn(naive_mv, Lx, Pj.reshape(-1))
        out.append(row(f"xmv_naive_n{n}", us, "precomputed-Lx-matvec"))

        elem = jax.jit(functools.partial(xmv_elementwise, edge_kernel=EK,
                                         chunk=8))
        us = time_fn(elem, Aj, Ej, Aj, Ej, Pj)
        out.append(row(f"xmv_onthefly_elementwise_n{n}", us,
                       "paper-faithful-Alg2"))

        wa = jax.jit(functools.partial(weighted_operands,
                                       edge_kernel=EK))(Aj, Ej)
        lr = jax.jit(xmv_lowrank_precomputed)
        us = time_fn(lr, wa, wa, Pj)
        out.append(row(f"xmv_lowrank_mxu_n{n}", us,
                       "beyond-paper-rank12-sandwich"))

        # Table I analytic arithmetic intensity for the Pallas tiling
        ti, tj, tip, tjp = 8, 16, 8, 128
        X, Ebytes, F = 8.0, 4, 4   # kappa_SE ~8 flops; f32 labels/weights
        ai_global = (ti * tip * X) / ((Ebytes + 2 * F) *
                                      (ti + tip) / 2 / min(ti, tip))
        out.append(row(f"xmv_tiling_ai_n{n}", 0.0,
                       f"analytic-AI={ai_global:.1f}flops/byte"))
    return out


if __name__ == "__main__":
    run()
