"""PR 1 perf tracking: the CG hot-path before/after comparison.

Emits ``BENCH_xmv.json`` with

* per-matvec wall time of the block-sparse bucket XMV, legacy
  loop-of-launches (one ``pallas_call`` + jit dispatch per pair) vs the
  batched grid (ONE launch for the whole bucket), at several bucket
  sizes B;
* fused diagonal epilogue vs the two-step ``diag*p - y`` reference on
  the dense batched path;
* classic vs pipelined PCG on the same product systems: wall time per
  solve and the per-pair iteration counts (must agree within ±1).

Numbers here come from the CPU/interpret harness — the absolute times
are not TPU times, but the *launch-count* effect the batched grid
removes (B separate kernel dispatches per CG iteration in the legacy
eager path) is exactly what they measure: both arms are timed as they
were invoked from the driver, i.e. the legacy arm pays its per-pair
dispatch just as ``ops.xmv_block_sparse_batched`` (the Python loop) did.
"""
from __future__ import annotations

import json

import numpy as np

import jax.numpy as jnp

from repro.core.base_kernels import KroneckerDelta, SquareExponential
from repro.core.graph import batch_from_graphs
from repro.core.mgk import mgk_pairs_sparse
from repro.data import make_drugbank_like_dataset
from repro.kernels.ops import packs_for_batch, xmv_block_sparse_unrolled
from repro.kernels.xmv_block_sparse import xmv_block_sparse_batched
from repro.kernels.xmv_dense import xmv_dense_batched
from .common import row, time_fn

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)


def _bucket(B: int, pad_to: int, seed: int = 7):
    if pad_to < 6:
        raise ValueError(f"pad_to={pad_to} below the minimum graph size")
    gs = []
    for s in range(seed, seed + 100):
        cand = make_drugbank_like_dataset(2 * B, seed=s)
        gs += [g for g in cand if 6 <= g.n_nodes <= pad_to]
        if len(gs) >= 2 * B:
            break
    else:
        raise RuntimeError(
            f"could not draw {2 * B} graphs with n_nodes in [6, {pad_to}]")
    gs = gs[:2 * B]
    g1 = batch_from_graphs(gs[:B], pad_to=pad_to)
    g2 = batch_from_graphs(gs[B:], pad_to=pad_to)
    return g1, g2, packs_for_batch(g1), packs_for_batch(g2)


def run(out_path: str = "BENCH_xmv.json", sizes=(2, 8, 16),
        pad_to: int = 16, iters: int = 5) -> dict:
    rng = np.random.default_rng(0)
    report: dict = {"matvec_block_sparse": [], "fused_epilogue": {},
                    "pcg": {}}

    for B in sizes:
        g1, g2, p1, p2 = _bucket(B, pad_to)
        n = g1.adjacency.shape[1]
        P = jnp.asarray(rng.random((B, n, n)).astype(np.float32))

        us_unrolled = time_fn(
            lambda P: xmv_block_sparse_unrolled(p1, p2, P, EK),
            P, iters=iters)
        us_batched = time_fn(
            lambda P: xmv_block_sparse_batched(p1, p2, P, EK),
            P, iters=iters)
        speedup = us_unrolled / max(us_batched, 1e-9)
        report["matvec_block_sparse"].append({
            "B": B, "n": n,
            "us_per_matvec_unrolled": us_unrolled,
            "us_per_matvec_batched": us_batched,
            "speedup": speedup,
        })
        row(f"xmv_sparse_unrolled_B{B}", us_unrolled, "loop-of-launches")
        row(f"xmv_sparse_batched_B{B}", us_batched,
            f"one-launch-speedup={speedup:.2f}x")

    # fused diagonal epilogue vs separate XLA op (dense path, largest B)
    B = sizes[-1]
    g1, g2, p1, p2 = _bucket(B, pad_to)
    n = g1.adjacency.shape[1]
    P = jnp.asarray(rng.random((B, n, n)).astype(np.float32))
    diag = jnp.asarray(rng.random((B, n, n)).astype(np.float32) + 1.0)
    args = (g1.adjacency, g1.edge_labels, g2.adjacency, g2.edge_labels)

    def unfused(P):
        y = xmv_dense_batched(*args, P, EK)
        return diag * P - y

    def fused(P):
        return xmv_dense_batched(*args, P, EK, diag=diag)

    us_unfused = time_fn(unfused, P, iters=iters)
    us_fused = time_fn(fused, P, iters=iters)
    report["fused_epilogue"] = {
        "B": B, "n": n, "us_unfused": us_unfused, "us_fused": us_fused,
        "speedup": us_unfused / max(us_fused, 1e-9),
    }
    row(f"xmv_dense_unfused_B{B}", us_unfused, "separate-diag-op")
    row(f"xmv_dense_fused_B{B}", us_fused, "in-kernel-epilogue")

    # classic vs pipelined PCG on the real sparse product systems
    pcg = {}
    for variant in ("classic", "pipelined"):
        us = time_fn(
            lambda g1=g1, g2=g2: mgk_pairs_sparse(
                g1, g2, p1, p2, VK, EK, tol=1e-10,
                pcg_variant=variant).values,
            iters=max(2, iters // 2))
        res = mgk_pairs_sparse(g1, g2, p1, p2, VK, EK, tol=1e-10,
                               pcg_variant=variant)
        pcg[variant] = {
            "us_per_solve": us,
            "iterations": np.asarray(res.iterations).tolist(),
            "converged": bool(np.asarray(res.converged).all()),
        }
        row(f"pcg_{variant}_B{B}", us,
            f"iters={int(np.asarray(res.iterations).max())}")
    pcg["max_iteration_gap"] = int(np.abs(
        np.asarray(pcg["classic"]["iterations"])
        - np.asarray(pcg["pipelined"]["iterations"])).max())
    report["pcg"] = pcg

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return report


if __name__ == "__main__":
    run()
