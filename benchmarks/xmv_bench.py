"""PR 1/2/4 perf tracking: the CG hot-path before/after comparison.

Emits ``BENCH_xmv.json`` with

* per-matvec wall time of the block-sparse bucket XMV across the three
  kernel generations at several bucket sizes B: legacy loop-of-launches
  (one ``pallas_call`` + jit dispatch per pair), the PR-1 batched
  unrolled grid (one launch, a grid step per (slot, slot') pair), and
  the PR-2 row-panel kernel (one launch, one grid step per output
  block, in-kernel slot reduction over VMEM-staged tile rows) in both
  its elementwise and MXU-contraction modes;
* the same arms swept over octile edge t in {8, 16, 32} (the t^4 VPU
  broadcast vs rank-batched MXU matmul scaling; on this CPU harness the
  MXU mode's matmuls only pull ahead of the elementwise tensor at t=32,
  where 2*R*t^3 < t^4 — on real MXU hardware the crossover is earlier);
* fused diagonal epilogue vs the two-step ``diag*p - y`` reference on
  the dense batched path;
* classic vs pipelined PCG on the same product systems: wall time per
  solve, *marginal* wall time per iteration (obtained by differencing
  two ``fixed_iters`` trip counts, which cancels setup/dispatch
  overhead), and the per-pair iteration counts (must agree within ±1).

Numbers here come from the CPU/interpret harness — the absolute times
are not TPU times, but the *launch/grid-step count* effects the batched
grid and the row-panel kernel remove are exactly what they measure.

On the pipelined-PCG column: PR 1 recorded pipelined ~27% slower per
solve than classic here despite identical iteration counts. That is an
artifact of the harness, not a solver regression — see the
``pcg["note"]`` field this module emits and DESIGN.md §3.3: each
pipelined iteration runs ~2x the [B, n*m] vector updates (p, s, x, r, u
recurrences + masking vs classic's three AXPYs) plus one extra matvec at
setup (w0 = A u0), costs that XLA op overhead amplifies on a single
interpret-mode CPU device, while the benefit — one all-reduce round per
iteration instead of two — only exists when CG dot products cross
devices. The marginal per-iteration numbers keep the two effects from
being conflated with launch overhead.
"""
from __future__ import annotations

import json

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.base_kernels import KroneckerDelta, SquareExponential
from repro.core.graph import batch_from_graphs
from repro.core.mgk import mgk_pairs_sparse, mgk_pairs_sparse_segmented
from repro.data import make_drugbank_like_dataset
from repro.kernels.ops import packs_for_batch, row_panel_packs_for_batch, \
    xmv_block_sparse_unrolled
from repro.kernels.xmv_block_sparse import xmv_block_sparse_batched, \
    xmv_gram_tile, xmv_row_panel_batched
from repro.kernels.xmv_dense import xmv_dense_batched
from .common import row, time_fn

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)

PCG_NOTE = (
    "pipelined > classic per solve on this single-device interpret"
    " harness is expected, not a regression: iteration counts are"
    " identical, but each pipelined iteration performs ~2x the [B, n*m]"
    " vector updates (p/s/x/r/u recurrences + convergence masking vs"
    " classic's three AXPYs) plus one extra matvec at setup (w0 = A u0)."
    " The variant trades those flops for ONE cross-device all-reduce"
    " round per iteration instead of two; with no 'model'-axis sharding"
    " here there is no reduction latency to win back, so only the extra"
    " vector work is visible. us_per_iteration_marginal (fixed_iters"
    " differencing) isolates the loop body from dispatch/setup overhead"
    " so reduction-latency wins on real meshes aren't conflated with"
    " interpret-mode op overhead.")


def _bucket(B: int, pad_to: int, seed: int = 7):
    if pad_to < 6:
        raise ValueError(f"pad_to={pad_to} below the minimum graph size")
    gs = []
    for s in range(seed, seed + 100):
        cand = make_drugbank_like_dataset(2 * B, seed=s)
        gs += [g for g in cand if 6 <= g.n_nodes <= pad_to]
        if len(gs) >= 2 * B:
            break
    else:
        raise RuntimeError(
            f"could not draw {2 * B} graphs with n_nodes in [6, {pad_to}]")
    gs = gs[:2 * B]
    g1 = batch_from_graphs(gs[:B], pad_to=pad_to)
    g2 = batch_from_graphs(gs[B:], pad_to=pad_to)
    return g1, g2


def _sparse_arms(g1, g2, P, iters, tile: int = 8, with_unrolled=True):
    """Time every block-sparse kernel generation on one bucket."""
    p1 = packs_for_batch(g1, tile=tile)
    p2 = packs_for_batch(g2, tile=tile)
    r1 = row_panel_packs_for_batch(g1, tile=tile)
    r2 = row_panel_packs_for_batch(g2, tile=tile)
    r1w = row_panel_packs_for_batch(g1, tile=tile, edge_kernel=EK)
    r2w = row_panel_packs_for_batch(g2, tile=tile, edge_kernel=EK)
    out = {}
    if with_unrolled:
        out["us_per_matvec_unrolled"] = time_fn(
            lambda P: xmv_block_sparse_unrolled(p1, p2, P, EK),
            P, iters=iters)
    out["us_per_matvec_batched"] = time_fn(
        lambda P: xmv_block_sparse_batched(p1, p2, P, EK), P, iters=iters)
    out["us_per_matvec_row_panel"] = time_fn(
        lambda P: xmv_row_panel_batched(r1, r2, P, EK, mode="elementwise"),
        P, iters=iters)
    out["us_per_matvec_row_panel_mxu"] = time_fn(
        lambda P: xmv_row_panel_batched(r1w, r2w, P, EK, mode="mxu"),
        P, iters=iters)
    return out


def run(out_path: str = "BENCH_xmv.json", sizes=(2, 8, 16),
        pad_to: int = 32, iters: int = 5, tiles=(8, 16, 32),
        tile_pad_to: int = 32, tile_B: int = 4) -> dict:
    rng = np.random.default_rng(0)
    report: dict = {"matvec_block_sparse": [], "matvec_tile_sweep": [],
                    "fused_epilogue": {}, "pcg": {}}

    for B in sizes:
        g1, g2 = _bucket(B, pad_to)
        n = g1.adjacency.shape[1]
        P = jnp.asarray(rng.random((B, n, n)).astype(np.float32))
        arms = _sparse_arms(g1, g2, P, iters)
        batched = arms["us_per_matvec_batched"]
        entry = {"B": B, "n": n, "tile": 8, **arms,
                 "speedup": arms["us_per_matvec_unrolled"]
                 / max(batched, 1e-9),
                 "speedup_row_panel_vs_batched": batched
                 / max(arms["us_per_matvec_row_panel"], 1e-9),
                 "speedup_row_panel_mxu_vs_batched": batched
                 / max(arms["us_per_matvec_row_panel_mxu"], 1e-9)}
        report["matvec_block_sparse"].append(entry)
        row(f"xmv_sparse_unrolled_B{B}", arms["us_per_matvec_unrolled"],
            "loop-of-launches")
        row(f"xmv_sparse_batched_B{B}", batched,
            f"one-launch-speedup={entry['speedup']:.2f}x")
        row(f"xmv_sparse_row_panel_B{B}", arms["us_per_matvec_row_panel"],
            f"vs-batched={entry['speedup_row_panel_vs_batched']:.2f}x")
        row(f"xmv_sparse_row_panel_mxu_B{B}",
            arms["us_per_matvec_row_panel_mxu"],
            f"vs-batched={entry['speedup_row_panel_mxu_vs_batched']:.2f}x")

    # octile-edge sweep: the t^4 VPU tensor vs rank-batched MXU matmuls
    for t in tiles:
        if tile_pad_to % t:
            continue
        g1, g2 = _bucket(tile_B, tile_pad_to)
        n = g1.adjacency.shape[1]
        P = jnp.asarray(rng.random((tile_B, n, n)).astype(np.float32))
        arms = _sparse_arms(g1, g2, P, iters, tile=t, with_unrolled=False)
        batched = arms["us_per_matvec_batched"]
        entry = {"B": tile_B, "n": n, "tile": t, **arms,
                 "speedup_row_panel_vs_batched": batched
                 / max(arms["us_per_matvec_row_panel"], 1e-9),
                 "speedup_row_panel_mxu_vs_batched": batched
                 / max(arms["us_per_matvec_row_panel_mxu"], 1e-9)}
        report["matvec_tile_sweep"].append(entry)
        row(f"xmv_sparse_row_panel_t{t}", arms["us_per_matvec_row_panel"],
            f"vs-batched={entry['speedup_row_panel_vs_batched']:.2f}x")
        row(f"xmv_sparse_row_panel_mxu_t{t}",
            arms["us_per_matvec_row_panel_mxu"],
            f"vs-batched={entry['speedup_row_panel_mxu_vs_batched']:.2f}x")

    # fused diagonal epilogue vs separate XLA op (dense path, largest B)
    B = sizes[-1]
    g1, g2 = _bucket(B, pad_to)
    n = g1.adjacency.shape[1]
    P = jnp.asarray(rng.random((B, n, n)).astype(np.float32))
    diag = jnp.asarray(rng.random((B, n, n)).astype(np.float32) + 1.0)
    args = (g1.adjacency, g1.edge_labels, g2.adjacency, g2.edge_labels)

    def unfused(P):
        y = xmv_dense_batched(*args, P, EK)
        return diag * P - y

    def fused(P):
        return xmv_dense_batched(*args, P, EK, diag=diag)

    us_unfused = time_fn(unfused, P, iters=iters)
    us_fused = time_fn(fused, P, iters=iters)
    report["fused_epilogue"] = {
        "B": B, "n": n, "us_unfused": us_unfused, "us_fused": us_fused,
        "speedup": us_unfused / max(us_fused, 1e-9),
    }
    row(f"xmv_dense_unfused_B{B}", us_unfused, "separate-diag-op")
    row(f"xmv_dense_fused_B{B}", us_fused, "in-kernel-epilogue")

    # classic vs pipelined PCG on the real sparse product systems (the
    # production row-panel MXU matvec)
    p1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
    p2 = row_panel_packs_for_batch(g2, edge_kernel=EK)
    pcg: dict = {}
    k_lo, k_hi = 5, 15
    for variant in ("classic", "pipelined"):
        def solve(fixed=None, variant=variant):
            return mgk_pairs_sparse(g1, g2, p1, p2, VK, EK, tol=1e-10,
                                    fixed_iters=fixed,
                                    pcg_variant=variant).values

        us = time_fn(solve, iters=max(2, iters // 2))
        us_lo = time_fn(lambda: solve(k_lo), iters=max(2, iters // 2))
        us_hi = time_fn(lambda: solve(k_hi), iters=max(2, iters // 2))
        us_iter = (us_hi - us_lo) / (k_hi - k_lo)
        res = mgk_pairs_sparse(g1, g2, p1, p2, VK, EK, tol=1e-10,
                               pcg_variant=variant)
        pcg[variant] = {
            "us_per_solve": us,
            "us_per_iteration_marginal": us_iter,
            "iterations": np.asarray(res.iterations).tolist(),
            "converged": bool(np.asarray(res.converged).all()),
        }
        row(f"pcg_{variant}_B{B}", us,
            f"iters={int(np.asarray(res.iterations).max())}"
            f",us/iter={us_iter:.1f}")
    pcg["max_iteration_gap"] = int(np.abs(
        np.asarray(pcg["classic"]["iterations"])
        - np.asarray(pcg["pipelined"]["iterations"])).max())
    pcg["note"] = PCG_NOTE
    report["pcg"] = pcg

    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return report


def _gram_batches(Bi: int, Bj: int, pad_to: int, seed: int = 7):
    """(row-axis batch [Bi], col-axis batch [Bj], flattened pair
    batches [Bi*Bj] in row-major pair order)."""
    g1u, g2u = _bucket(max(Bi, Bj), pad_to, seed=seed)
    g1u = jax.tree.map(lambda x: x[:Bi], g1u)
    g2u = jax.tree.map(lambda x: x[:Bj], g2u)
    rep = lambda x: jnp.repeat(x, Bj, axis=0)                   # noqa
    til = lambda x: jnp.tile(x, (Bi,) + (1,) * (x.ndim - 1))    # noqa
    return g1u, g2u, jax.tree.map(rep, g1u), jax.tree.map(til, g2u)


def run_gram(out_path: str = "BENCH_gram.json",
             shapes=((2, 2), (4, 4), (8, 8)), pad_to: int = 32,
             iters: int = 5, segment_size: int = 4) -> dict:
    """PR 4: Gram-tile hot path vs stacked per-pair row-panel, plus
    convergence-segmented PCG vs masked lockstep.

    Per I x J Gram-tile shape:

    * per-matvec wall time of ``xmv_gram_tile`` (ONE pack per axis,
      (Bi, nt, Bj) grid, in-kernel output-column loop) against
      ``xmv_row_panel_batched`` over per-pair stacked packs (the PR-2
      production kernel) — both modes. On this interpret harness the
      win is the mt-fold grid-step reduction; on hardware it is that
      plus each row graph's panels fetched once per tile row instead of
      once per (pair, tile row).
    * matvecs-per-solve: total pair-matvec evaluations of the segmented
      solve (pairs RETIRE between segments) vs masked lockstep (every
      pair rides to the last pair's convergence), at identical final
      residuals.
    """
    rng = np.random.default_rng(0)
    report: dict = {"gram_tile": [], "segmented_pcg": []}
    for (Bi, Bj) in shapes:
        g1u, g2u, g1f, g2f = _gram_batches(Bi, Bj, pad_to)
        n = g1u.adjacency.shape[1]
        m = g2u.adjacency.shape[1]
        P4 = jnp.asarray(rng.random((Bi, Bj, n, m)).astype(np.float32))
        Pf = P4.reshape(Bi * Bj, n, m)
        # per-axis packs (Bi + Bj) vs per-pair stacked packs (Bi*Bj)
        a1 = row_panel_packs_for_batch(g1u)
        a2 = row_panel_packs_for_batch(g2u)
        a1w = row_panel_packs_for_batch(g1u, edge_kernel=EK)
        a2w = row_panel_packs_for_batch(g2u, edge_kernel=EK)
        p1 = row_panel_packs_for_batch(g1f)
        p2 = row_panel_packs_for_batch(g2f)
        p1w = row_panel_packs_for_batch(g1f, edge_kernel=EK)
        p2w = row_panel_packs_for_batch(g2f, edge_kernel=EK)
        entry = {"Bi": Bi, "Bj": Bj, "n": n, "tile": 8}
        entry["us_per_matvec_per_pair"] = time_fn(
            lambda P: xmv_row_panel_batched(p1, p2, P, EK,
                                            mode="elementwise"),
            Pf, iters=iters)
        entry["us_per_matvec_gram_tile"] = time_fn(
            lambda P: xmv_gram_tile(a1, a2, P, EK, mode="elementwise"),
            P4, iters=iters)
        entry["us_per_matvec_per_pair_mxu"] = time_fn(
            lambda P: xmv_row_panel_batched(p1w, p2w, P, EK, mode="mxu"),
            Pf, iters=iters)
        entry["us_per_matvec_gram_tile_mxu"] = time_fn(
            lambda P: xmv_gram_tile(a1w, a2w, P, EK, mode="mxu"),
            P4, iters=iters)
        entry["speedup_gram_tile_vs_per_pair"] = \
            entry["us_per_matvec_per_pair"] / max(
                entry["us_per_matvec_gram_tile"], 1e-9)
        entry["speedup_gram_tile_vs_per_pair_mxu"] = \
            entry["us_per_matvec_per_pair_mxu"] / max(
                entry["us_per_matvec_gram_tile_mxu"], 1e-9)
        report["gram_tile"].append(entry)
        row(f"xmv_gram_tile_{Bi}x{Bj}", entry["us_per_matvec_gram_tile"],
            f"vs-per-pair={entry['speedup_gram_tile_vs_per_pair']:.2f}x")
        row(f"xmv_gram_tile_mxu_{Bi}x{Bj}",
            entry["us_per_matvec_gram_tile_mxu"],
            f"vs-per-pair="
            f"{entry['speedup_gram_tile_vs_per_pair_mxu']:.2f}x")

        # segmented PCG vs masked lockstep on the same Gram tile (a
        # mixed-convergence bucket: iteration counts vary per pair)
        lock = mgk_pairs_sparse(g1f, g2f, a1w, a2w, VK, EK, tol=1e-10,
                                gram_tile=(Bi, Bj))
        seg = mgk_pairs_sparse_segmented(
            g1f, g2f, a1w, a2w, VK, EK, tol=1e-10,
            segment_size=segment_size, gram_tile=(Bi, Bj))
        its = np.asarray(lock.iterations)
        seg_entry = {
            "Bi": Bi, "Bj": Bj, "segment_size": segment_size,
            "matvec_pairs_lockstep": int(lock.matvec_pairs),
            "matvec_pairs_segmented": int(seg.matvec_pairs),
            "iterations_min": int(its.min()),
            "iterations_max": int(its.max()),
            "iterations_match": bool(np.array_equal(
                its, np.asarray(seg.iterations))),
            "values_max_rel_err": float(np.max(np.abs(
                (np.asarray(seg.values) - np.asarray(lock.values))
                / np.maximum(np.abs(np.asarray(lock.values)), 1e-30)))),
            "savings": 1.0 - int(seg.matvec_pairs)
            / max(int(lock.matvec_pairs), 1),
        }
        report["segmented_pcg"].append(seg_entry)
        row(f"pcg_segmented_{Bi}x{Bj}",
            float(seg_entry["matvec_pairs_segmented"]),
            f"lockstep={seg_entry['matvec_pairs_lockstep']}"
            f",savings={seg_entry['savings']:.1%}")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return report


if __name__ == "__main__":
    run()
    run_gram()
