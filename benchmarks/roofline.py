"""§Roofline table generator: reads the dry-run JSON records and emits
the per-(arch x shape x mesh) three-term roofline table (markdown + CSV).

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .common import row

HEADERS = ["arch", "shape", "mesh", "variant", "compute_s", "memory_s",
           "collective_s", "dominant", "model_flops_ratio"]


def load(dir_: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict]) -> str:
    lines = ["| " + " | ".join(HEADERS) + " |",
             "|" + "---|" * len(HEADERS)]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                         r.get("variant", ""))):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r.get('variant', '')} | skip | skip | skip | "
                         f"— | — |")
            continue
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        mfr = ro.get("model_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('variant', '')} | {ro['compute_s']:.3e} | "
            f"{ro['memory_s']:.3e} | {ro['collective_s']:.3e} | "
            f"{ro['dominant'][:-2]} | "
            f"{'—' if mfr is None else f'{mfr:.2f}'} |")
    return "\n".join(lines)


def run(dir_: str = "results/dryrun") -> list[str]:
    recs = [r for r in load(dir_) if r.get("status") == "ok"]
    out = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         r["mesh"])):
        ro = r["roofline"]
        dom_val = ro[ro["dominant"]]
        out.append(row(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
            f"_{r.get('variant', 'baseline')}",
            dom_val * 1e6,
            f"dominant={ro['dominant'][:-2]};compute={ro['compute_s']:.2e};"
            f"memory={ro['memory_s']:.2e};"
            f"collective={ro['collective_s']:.2e}"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--markdown", action="store_true")
    a = ap.parse_args()
    if a.markdown:
        print(table(load(a.dir)))
    else:
        run(a.dir)
