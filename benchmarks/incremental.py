"""Paper Fig. 9 analog: the optimization ladder. Each rung keeps
everything from the rung below and adds one technique; we report
time-to-solution of a fixed batch of kernel evaluations (CPU wall clock,
XLA-jitted -> relative speedups are the signal):

  dense        naive full-product XMV inside CG
  sparse       block-sparse octile XMV (natural order)
  +reorder     PBR reordering before packing
  +lowrank     beyond-paper MXU sandwich XMV (rank-12 SE features)
"""
from __future__ import annotations

import numpy as np

from repro.core import KroneckerDelta, SquareExponential, \
    batch_from_graphs, mgk_pairs, pbr_order
from repro.core.mgk import mgk_pairs_sparse
from repro.data import make_drugbank_like_dataset, make_synthetic_dataset
from repro.kernels.ops import packs_for_batch
from .common import row, time_fn

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=12)


def _pairs(dataset: str, n_pairs: int = 4):
    if dataset == "nws":
        gs = make_synthetic_dataset("nws", n_graphs=2 * n_pairs,
                                    n_nodes=48, seed=0)
    elif dataset == "ba":
        gs = make_synthetic_dataset("ba", n_graphs=2 * n_pairs,
                                    n_nodes=48, seed=0)
    else:
        gs = [g for g in make_drugbank_like_dataset(40, seed=0)
              if 24 <= g.n_nodes <= 64][:2 * n_pairs]
    a = gs[:n_pairs]
    b = gs[n_pairs:2 * n_pairs]
    return a, b


def run(datasets=("nws", "ba", "drugbank_like")) -> list[str]:
    out = []
    for ds in datasets:
        ga, gb = _pairs(ds)
        pad = max(max(g.n_nodes for g in ga), max(g.n_nodes for g in gb))
        pad = -(-pad // 8) * 8
        A = batch_from_graphs(ga, pad_to=pad)
        B = batch_from_graphs(gb, pad_to=pad)

        us = time_fn(lambda a, b: mgk_pairs(a, b, VK, EK, method="full",
                                            tol=1e-8).values, A, B, iters=3)
        base = us
        out.append(row(f"ladder_{ds}_dense", us, "speedup=1.00x"))

        packs_a, packs_b = packs_for_batch(A), packs_for_batch(B)
        us = time_fn(lambda a, b, pa, pb: mgk_pairs_sparse(
            a, b, pa, pb, VK, EK, tol=1e-8).values,
            A, B, packs_a, packs_b, iters=3)
        out.append(row(f"ladder_{ds}_sparse", us,
                       f"speedup={base / us:.2f}x"))

        ga_r = [g.permuted(pbr_order(g.adjacency)) for g in ga]
        gb_r = [g.permuted(pbr_order(g.adjacency)) for g in gb]
        Ar = batch_from_graphs(ga_r, pad_to=pad)
        Br = batch_from_graphs(gb_r, pad_to=pad)
        pa_r, pb_r = packs_for_batch(Ar), packs_for_batch(Br)
        us = time_fn(lambda a, b, pa, pb: mgk_pairs_sparse(
            a, b, pa, pb, VK, EK, tol=1e-8).values,
            Ar, Br, pa_r, pb_r, iters=3)
        out.append(row(f"ladder_{ds}_sparse_reorder", us,
                       f"speedup={base / us:.2f}x"))

        us = time_fn(lambda a, b: mgk_pairs(a, b, VK, EK, method="lowrank",
                                            tol=1e-8).values, A, B, iters=3)
        out.append(row(f"ladder_{ds}_lowrank_mxu", us,
                       f"speedup={base / us:.2f}x"))
    return out


if __name__ == "__main__":
    run()
