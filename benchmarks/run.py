"""Benchmark driver — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV (plus a roofline section read from the
dry-run records if present) and writes ``BENCH_xmv.json`` (the PR-1
hot-path before/after numbers).

    PYTHONPATH=src python -m benchmarks.run [--smoke]

``--smoke`` runs a CI-sized subset: the XMV hot-path comparison at small
sizes plus the primitive sweep at one size. Everything else is the full
(slow) paper-figure sweep.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI subset (small sizes)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    from . import xmv_bench
    from . import pcg_bench
    from . import faults_bench
    if args.smoke:
        from . import primitives
        primitives.run(sizes=(32,))
        xmv_bench.run(sizes=(2, 8), pad_to=32, iters=3, tiles=(8, 16, 32),
                      tile_B=2)
        xmv_bench.run_gram(shapes=((2, 2), (4, 4)), iters=3)
        # PR 5: jacobi vs kron + bf16 bytes. iters=5 timing reps (the
        # iteration-count asserts are deterministic; the wall-clock
        # asserts need a stable median on a contended CI runner)
        pcg_bench.run(iters=5)
        # PR 6: fault campaign (bitwise identity + guard overhead)
        faults_bench.run(n_graphs=6, B=2, iters=3)
        return
    from . import primitives, reorder_bench, adaptive, incremental, \
        packages, roofline
    primitives.run()          # paper Fig. 5 / Table I
    xmv_bench.run()           # PR 1: batched-grid + fused + pipelined CG
    xmv_bench.run_gram()      # PR 4: Gram-tile kernel + segmented PCG
    pcg_bench.run()           # PR 5: Kronecker preconditioner + bf16
    faults_bench.run()        # PR 6: self-healing build + guard cost
    reorder_bench.run()       # paper Figs. 6-7
    adaptive.run()            # paper Fig. 8
    incremental.run()         # paper Fig. 9
    packages.run()            # paper Fig. 10
    if os.path.isdir("results/dryrun"):
        roofline.run("results/dryrun")   # EXPERIMENTS §Roofline source


if __name__ == "__main__":
    main()
