"""Benchmark driver — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV (plus a roofline section read from the
dry-run records if present).

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    print("name,us_per_call,derived")
    from . import primitives, reorder_bench, adaptive, incremental, \
        packages, roofline
    primitives.run()          # paper Fig. 5 / Table I
    reorder_bench.run()       # paper Figs. 6-7
    adaptive.run()            # paper Fig. 8
    incremental.run()         # paper Fig. 9
    packages.run()            # paper Fig. 10
    if os.path.isdir("results/dryrun"):
        roofline.run("results/dryrun")   # EXPERIMENTS §Roofline source


if __name__ == "__main__":
    main()
