"""Paper Fig. 8 analog: dense vs block-sparse XMV crossover by tile
occupancy. Both kernels run in the same (interpret) mode so the relative
ordering is meaningful; the derived column reports the work-model ratio
(active tile products vs all tile products) that the production dispatch
uses to pick a primitive."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.base_kernels import SquareExponential
from repro.core.octile import octile_decompose
from repro.kernels.xmv_block_sparse import pack_graph, xmv_block_sparse
from repro.kernels.xmv_dense import xmv_dense
from .common import row, time_fn

EK = SquareExponential(1.0, rank=10)


def _graph_with_density(rng, n, target_nnz_per_tile):
    """Random graph whose non-empty octiles hold ~target nnz each."""
    a = np.zeros((n, n), np.float32)
    nt = n // 8
    for ti in range(nt):
        for tj in range(ti, nt):
            if rng.random() < 0.35:      # ~1/3 of tiles non-empty
                k = min(64, max(1, int(rng.normal(target_nnz_per_tile, 2))))
                idx = rng.choice(64, size=k, replace=False)
                for f in idx:
                    i, j = ti * 8 + f // 8, tj * 8 + f % 8
                    a[i, j] = a[j, i] = 1.0
    e = rng.random((n, n)).astype(np.float32) * (a != 0)
    return a, e


def run(n: int = 64, occupancies=(2, 8, 16, 32, 56)) -> list[str]:
    rng = np.random.default_rng(0)
    out = []
    for occ in occupancies:
        A, E = _graph_with_density(rng, n, occ)
        P = jnp.asarray(rng.random((n, n), np.float32))
        Aj, Ej = jnp.asarray(A), jnp.asarray(E)
        us_d = time_fn(lambda a, e, p: xmv_dense(a, e, a, e, p, EK),
                       Aj, Ej, P, iters=3)
        p1 = pack_graph(A, E)
        us_s = time_fn(lambda pk, p: xmv_block_sparse(pk, pk, p, EK),
                       p1, P, iters=3)
        oset = octile_decompose(A, E)
        frac = oset.n_nonempty / max((n // 8) ** 2, 1)
        work_ratio = frac ** 2      # tile-pair products touched
        winner = "sparse" if us_s < us_d else "dense"
        out.append(row(f"adaptive_occ{occ}", min(us_d, us_s),
                       f"dense_us={us_d:.0f};sparse_us={us_s:.0f};"
                       f"work_ratio={work_ratio:.3f};winner={winner}"))
    return out


if __name__ == "__main__":
    run()
