"""PR 6 robustness tracking: self-healing Gram builds under faults.

Emits ``BENCH_faults.json`` with two sections:

* **guard** — the cost of the per-pair PCG numerical guards
  (core/pcg.py) on the CLEAN hot path: the same bucket solved at a
  FIXED trip count with ``guard=True`` vs ``guard=False``, so both arms
  execute identical matvec work and the difference is pure guard
  arithmetic (a handful of [B] scalar checks per iteration). CI asserts
  this overhead stays < 5% — the guards are meant to be always-on.
* **campaign** — a full Gram build driven through the seeded fault
  campaign (``distributed/faults.py``: mid-build driver kill, chunk
  corruption + truncation on disk, injected matvec NaNs, forced
  kron-certificate failure) versus a fault-free build of the same
  dataset. Asserts the healed result is BITWISE-IDENTICAL to the clean
  one with zero NaN entries, reports the injection ledger, restart
  count, retry/escalation totals, and the wall-clock recovery overhead
  (the price of recomputing faulted blocks — informational, it scales
  with the injected fault rate, not with code quality).

Numbers come from the CPU/interpret harness: absolute times are not
TPU times, but the guard-overhead RATIO is arithmetic the accelerator
sees too (same guard ops per iteration), and bitwise identity is exact.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import numpy as np

import jax
from jax.sharding import Mesh

from repro.core import KroneckerDelta, SquareExponential
from repro.core.mgk import mgk_pairs_sparse
from repro.data import bucket_graphs, make_drugbank_like_dataset
from repro.distributed import ChunkStore, FaultPlan, GramDriver, \
    run_campaign
from repro.kernels.ops import row_panel_packs_for_batch
from .common import row, time_fn

VK = KroneckerDelta(0.5, n_labels=8)
EK = SquareExponential(1.0, rank=10)

# the campaign every build must heal from (seeded => reproducible):
# roughly half the blocks see a transient matvec NaN, a third get their
# chunk corrupted on disk, plus truncation, forced certificate failure
# and one mid-build driver kill
CAMPAIGN = FaultPlan(seed=3, kill_after_blocks=3, corrupt_fraction=0.3,
                     truncate_fraction=0.2, matvec_nan_fraction=0.5,
                     cert_fail_fraction=0.4)


def _dataset(n_graphs: int, seed: int):
    gs = [g for g in make_drugbank_like_dataset(n_graphs + 8, seed=seed)
          if g.n_nodes >= 4][:n_graphs]
    return bucket_graphs(gs, max_buckets=3)


def _mesh() -> Mesh:
    return Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))


def _guard_overhead(report: dict, B: int, seed: int, fixed_iters: int,
                    iters: int) -> None:
    """guard=True vs guard=False at a fixed trip count on one bucket."""
    gs = []
    for s in range(seed, seed + 50):
        gs += [g for g in make_drugbank_like_dataset(4 * B, seed=s)
               if 6 <= g.n_nodes <= 24]
        if len(gs) >= 2 * B:
            break
    gs = gs[:2 * B]
    from repro.core.graph import batch_from_graphs
    pad = max(g.n_nodes for g in gs)
    pad += (-pad) % 8
    g1 = batch_from_graphs(gs[:B], pad_to=pad)
    g2 = batch_from_graphs(gs[B:2 * B], pad_to=pad)
    p1 = row_panel_packs_for_batch(g1, edge_kernel=EK)
    p2 = row_panel_packs_for_batch(g2, edge_kernel=EK)

    def solve(guard):
        return mgk_pairs_sparse(g1, g2, p1, p2, VK, EK,
                                sparse_mode="mxu",
                                fixed_iters=fixed_iters,
                                guard=guard)

    # identical trip counts => identical matvec work in both arms
    r_on, r_off = solve(True), solve(False)
    np.testing.assert_allclose(np.asarray(r_on.values),
                               np.asarray(r_off.values), rtol=1e-6)
    us_off = time_fn(lambda: solve(False).values.block_until_ready(),
                     iters=iters)
    us_on = time_fn(lambda: solve(True).values.block_until_ready(),
                    iters=iters)
    overhead = us_on / max(us_off, 1e-9) - 1.0
    report["guard"] = {
        "B": B, "fixed_iters": fixed_iters,
        "us_unguarded": us_off, "us_guarded": us_on,
        "overhead": overhead,
    }
    row("guard_off", us_off, f"fixed_iters={fixed_iters}")
    row("guard_on", us_on, f"overhead={overhead:+.1%}")


def _campaign(report: dict, n_graphs: int, pairs_per_block: int,
              seed: int) -> None:
    """Clean build vs the same build through the fault campaign."""
    tmp = tempfile.mkdtemp(prefix="faults_bench_")
    try:
        ds = _dataset(n_graphs, seed)
        mesh = _mesh()

        def driver(store_dir, injector=None):
            return GramDriver(ds, mesh, VK, EK,
                              store=ChunkStore(store_dir),
                              method="pallas_sparse", precond="kron",
                              pairs_per_block=pairs_per_block,
                              faults=injector)

        # warm the jit caches so both timed arms pay only solve time
        driver(os.path.join(tmp, "warm")).run()

        t0 = time.perf_counter()
        clean_driver = driver(os.path.join(tmp, "clean"))
        K_clean = clean_driver.run()
        t_clean = time.perf_counter() - t0

        t0 = time.perf_counter()
        K_fault, rep = run_campaign(
            lambda inj: driver(os.path.join(tmp, "faulty"), inj),
            CAMPAIGN)
        t_fault = time.perf_counter() - t0

        identical = bool(np.array_equal(K_clean, K_fault))
        n_nan = int(np.isnan(K_fault).sum())
        health = rep["health"]
        store = ChunkStore(os.path.join(tmp, "faulty"))
        recovered = {bid: entry for bid, entry in
                     ((b, store.block_entry(b))
                      for b in store.done_blocks())
                     if entry and "recovery" in entry}
        report["campaign"] = {
            "n_graphs": n_graphs, "pairs_per_block": pairs_per_block,
            "seed": CAMPAIGN.seed,
            "restarts": rep["restarts"],
            "injections": rep["injections"],
            "retries": health.get("retries", 0),
            "escalations": health.get("escalations", 0),
            "quarantined_pairs": health.get("quarantined_pairs", []),
            "recovered_blocks_in_manifest": sorted(recovered),
            "bitwise_identical": identical,
            "nan_entries": n_nan,
            "s_clean": t_clean, "s_faulted": t_fault,
            "recovery_overhead": t_fault / max(t_clean, 1e-9) - 1.0,
        }
        assert identical, \
            "faulted build is NOT bitwise-identical to the clean build"
        assert n_nan == 0, f"{n_nan} silent NaN entries in healed Gram"
        row("gram_clean", t_clean * 1e6,
            f"blocks={len(store.done_blocks())}")
        row("gram_faulted", t_fault * 1e6,
            f"restarts={rep['restarts']}"
            f",inj={sum(rep['injections'].values())}"
            f",overhead={report['campaign']['recovery_overhead']:+.1%}"
            f",identical={identical}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(out_path: str = "BENCH_faults.json", n_graphs: int = 8,
        pairs_per_block: int = 8, B: int = 4, fixed_iters: int = 32,
        iters: int = 5, seed: int = 7) -> dict:
    report: dict = {}
    _guard_overhead(report, B, seed, fixed_iters, iters)
    _campaign(report, n_graphs, pairs_per_block, seed)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path}", flush=True)
    return report


if __name__ == "__main__":
    run()
