"""End-to-end driver: train a ~100M-parameter qwen3-family model for a
few hundred steps on synthetic data with checkpointing (the tasking's
(b) deliverable).

    PYTHONPATH=src python examples/lm_train.py --steps 200
"""
import sys, os, argparse, dataclasses
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS
from repro.launch.train import TrainRun, run_training


def config_100m():
    """A ~100M-param member of the qwen3 family (same code path as the
    full 14B config)."""
    return dataclasses.replace(
        ARCHS["qwen3-0.6b"],
        arch_id="qwen3-100m",
        n_layers=10, d_model=640, n_heads=10, n_kv_heads=2, head_dim=64,
        d_ff=2560, vocab_size=50304, dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_train_100m")
    args = ap.parse_args()
    cfg = config_100m()
    n = cfg.n_params()
    print(f"training {cfg.arch_id}: {n/1e6:.0f}M params, "
          f"{args.steps} steps")
    _, losses = run_training(TrainRun(
        cfg=cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=3e-4, ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10))
    print(f"loss: {losses[0][1]:.3f} -> {losses[-1][1]:.3f}")
    assert losses[-1][1] < losses[0][1]


if __name__ == "__main__":
    main()
