"""End-to-end production pipeline: reorder -> bucket -> schedule ->
sharded batched solve -> checkpointed Gram matrix, with a simulated
mid-run crash + restart (fault tolerance demo).

    PYTHONPATH=src python examples/gram_pipeline.py
"""
import sys, os, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import KroneckerDelta, SquareExponential, best_order
from repro.data import bucket_graphs, make_drugbank_like_dataset
from repro.distributed import ChunkStore, GramDriver


def main():
    graphs = [g for g in make_drugbank_like_dataset(24, seed=3)
              if g.n_nodes >= 4][:16]
    # production preprocessing: per-graph reordering for octile density
    reordered = []
    for g in graphs:
        perm, name, tiles = best_order(g.adjacency)
        reordered.append(g.permuted(perm))
    ds = bucket_graphs(reordered, max_buckets=3)
    print(f"{len(ds)} graphs in {len(ds.buckets)} buckets:",
          [(b.pad_to, len(b.indices)) for b in ds.buckets])

    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    with tempfile.TemporaryDirectory() as ckpt:
        store = ChunkStore(ckpt)
        drv = GramDriver(ds, mesh, KroneckerDelta(0.5, 8),
                         SquareExponential(1.0, rank=12), store=store,
                         pairs_per_block=24)
        plan = drv.plan()
        print(f"{len(drv.blocks())} pair-blocks, makespan ratio "
              f"{plan.makespan_ratio:.2f}")

        # simulate a crash: run a few blocks "before the failure"
        from repro.distributed.gram import gram_pair_step, solve_pair_block
        step = gram_pair_step(mesh, drv.vertex_kernel, drv.edge_kernel)
        for blk in drv.blocks()[:3]:
            store.save_block(blk.block_id,
                             **solve_pair_block(ds, blk, step, 1))
        print(f"'crash' after {len(store.done_blocks())} blocks; "
              "restarting...")

        K = drv.run(progress=lambda i, n: None)   # resumes, no recompute
        print("Gram complete:", K.shape, "min eig",
              np.linalg.eigvalsh(K).min().round(6))


if __name__ == "__main__":
    main()
