"""GP hyperparameter optimization on the differentiable MGK — the
"kernel-based learning at unprecedented scales" workload of the paper's
closing claim, made concrete: fit the vertex-kernel mismatch ``h``, the
edge-kernel bandwidth ``alpha``, and the stopping probability ``q`` by
gradient descent on the GP negative log marginal likelihood over a
bucketed synthetic dataset.

Every NLML gradient flows through the adjoint-PCG custom VJP
(core/adjoint.py, DESIGN.md §7): two PCG solves per pair batch per
step, no matter how many hyperparameters are being learned.

    PYTHONPATH=src python examples/gp_fit.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import KroneckerDelta, SquareExponential
from repro.core.adjoint import kernel_theta
from repro.data import bucket_graphs, make_drugbank_like_dataset
from repro.train.steps import make_gp_nlml, make_gp_step


def main():
    graphs = [g for g in make_drugbank_like_dataset(24, seed=7)
              if 5 <= g.n_nodes <= 32][:12]
    # synthetic property a label-aware walk kernel can explain: the
    # composition of vertex labels
    y = np.array([np.mean(g.vertex_labels == 0) for g in graphs],
                 np.float32)
    y = (y - y.mean()) / max(y.std(), 1e-6)

    ds = bucket_graphs(graphs, max_buckets=2)
    vk = KroneckerDelta(0.9, n_labels=8)          # deliberately off
    ek = SquareExponential(0.3, rank=12)
    nlml = make_gp_nlml(ds, y, vk, ek, method="lowrank", noise=1e-2,
                        tol=1e-8, max_iter=256)
    init, step = make_gp_step(nlml, lr=5e-2)

    theta = kernel_theta(vk, ek, q=0.05)
    theta, opt_state = init(theta)
    loss0 = float(nlml(theta))
    print(f"step  0: nlml {loss0:+.4f}  theta "
          f"h={float(theta['vertex']['h']):.3f} "
          f"alpha={float(theta['edge']['alpha']):.3f} "
          f"q={float(theta['q']):.3f}")
    for it in range(1, 16):
        theta, opt_state, loss = step(theta, opt_state)
        if it % 5 == 0 or it == 1:
            print(f"step {it:2d}: nlml {float(loss):+.4f}  theta "
                  f"h={float(theta['vertex']['h']):.3f} "
                  f"alpha={float(theta['edge']['alpha']):.3f} "
                  f"q={float(theta['q']):.3f}")
    loss1 = float(nlml(theta))
    print(f"final nlml {loss1:+.4f} (improved by {loss0 - loss1:+.4f})")
    assert loss1 < loss0, "gradient descent failed to reduce the NLML"


if __name__ == "__main__":
    main()
