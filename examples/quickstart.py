"""Quickstart: marginalized graph kernel between two molecules, then a
small normalized Gram matrix.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (KroneckerDelta, SquareExponential,
                        batch_from_graphs, mgk_pairs)
from repro.data import make_drugbank_like_dataset


def main():
    graphs = [g for g in make_drugbank_like_dataset(12, seed=0)
              if g.n_nodes >= 5][:6]
    vk = KroneckerDelta(h=0.5, n_labels=8)      # element identity
    ek = SquareExponential(alpha=1.0, rank=12)  # bond-length similarity

    # one pair, with the node-wise similarity map (paper Sec. I)
    a = batch_from_graphs(graphs[:1])
    b = batch_from_graphs(graphs[1:2], pad_to=a.padded_nodes) \
        if a.padded_nodes >= graphs[1].n_nodes else batch_from_graphs(graphs[1:2])
    a = batch_from_graphs(graphs[:1], pad_to=max(a.padded_nodes, b.padded_nodes))
    b = batch_from_graphs(graphs[1:2], pad_to=a.padded_nodes)
    res = mgk_pairs(a, b, vk, ek, return_nodal=True)
    print(f"K(G0, G1) = {float(res.values[0]):.6f} "
          f"({int(res.iterations[0])} CG iterations)")
    print("nodal similarity block:\n",
          np.asarray(res.nodal[0])[:4, :4].round(4))

    # small all-pairs normalized Gram matrix
    n = len(graphs)
    pad = max(g.n_nodes for g in graphs)
    pairs = [(i, j) for i in range(n) for j in range(i, n)]
    A = batch_from_graphs([graphs[i] for i, _ in pairs], pad_to=pad)
    B = batch_from_graphs([graphs[j] for _, j in pairs], pad_to=pad)
    vals = np.asarray(mgk_pairs(A, B, vk, ek).values)
    K = np.zeros((n, n))
    for (i, j), v in zip(pairs, vals):
        K[i, j] = K[j, i] = v
    d = np.sqrt(np.diag(K))
    K = K / d[:, None] / d[None, :]
    print("normalized Gram:\n", K.round(3))
    print("min eigenvalue:", np.linalg.eigvalsh(K).min().round(6))


if __name__ == "__main__":
    main()
