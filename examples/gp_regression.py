"""Kernel-based learning on graphs (the paper's motivating application):
kernel ridge regression of a synthetic molecular property using the
marginalized graph kernel Gram matrix.

    PYTHONPATH=src python examples/gp_regression.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import KroneckerDelta, SquareExponential
from repro.data import bucket_graphs, make_drugbank_like_dataset
from repro.distributed import GramDriver


def main():
    graphs = [g for g in make_drugbank_like_dataset(40, seed=1)
              if 5 <= g.n_nodes <= 48][:28]
    # synthetic target: label composition (what a vertex-label-aware
    # graph kernel can actually see)
    y = np.array([np.mean(g.vertex_labels == 0) for g in graphs])

    ds = bucket_graphs(graphs, max_buckets=3)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    K = GramDriver(ds, mesh, KroneckerDelta(0.5, 8),
                   SquareExponential(1.0, rank=12),
                   pairs_per_block=32).run()

    n_train = 20
    idx = np.random.default_rng(0).permutation(len(graphs))
    tr, te = idx[:n_train], idx[n_train:]
    lam = 1e-4
    alpha = np.linalg.solve(K[np.ix_(tr, tr)] + lam * np.eye(n_train),
                            y[tr])
    pred = K[np.ix_(te, tr)] @ alpha
    mae = np.abs(pred - y[te]).mean()
    base = np.abs(y[tr].mean() - y[te]).mean()
    print(f"kernel ridge MAE {mae:.4f} vs mean-predictor {base:.4f} "
          f"({base / mae:.1f}x better)")
    assert mae < base


if __name__ == "__main__":
    main()
