"""Slow numpy oracles for validating the solver end to end.

Two independent ground truths:

* :func:`mgk_direct` — build the explicit product system with ``np.kron``
  and solve it with a dense direct solver (LAPACK). This is also the
  "GraKeL-style explicit CPU solver" baseline of benchmarks/packages.py.
* :func:`mgk_walk_sum` — evaluate the kernel's *definition* (paper Eq. 4 /
  Eq. 9 fixed-point iteration) truncated at walk length L. Converges
  geometrically, so moderate L validates the linear-algebra reformulation
  itself.
"""
from __future__ import annotations

import numpy as np

from .graph import Graph

__all__ = ["mgk_direct", "mgk_walk_sum", "product_matrix"]


def _kappa_np(kernel, x, y):
    """Evaluate a BaseKernel on numpy inputs (via jnp, back to numpy)."""
    import jax.numpy as jnp
    return np.asarray(kernel(jnp.asarray(x), jnp.asarray(y)))


def product_matrix(g1: Graph, g2: Graph, vertex_kernel, edge_kernel
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Explicit (L_x, D_x q_x, p_x) of paper Eq. 15."""
    d1, d2 = g1.degrees(), g2.degrees()
    dx = np.kron(d1, d2)
    vx = _kappa_np(vertex_kernel,
                   np.repeat(g1.vertex_labels, g2.n_nodes),
                   np.tile(g2.vertex_labels, g1.n_nodes))
    Ax = np.kron(g1.adjacency, g2.adjacency)
    # generalized Kronecker product E (x)_kappa E'
    E1 = np.repeat(np.repeat(g1.edge_labels, g2.n_nodes, 0),
                   g2.n_nodes, 1)
    E2 = np.tile(g2.edge_labels, (g1.n_nodes, g1.n_nodes))
    Ex = _kappa_np(edge_kernel, E1, E2)
    Lx = np.diag(dx / vx) - Ax * Ex
    rhs = dx * np.kron(g1.stop_prob, g2.stop_prob)
    px = np.kron(g1.start_prob, g2.start_prob)
    return Lx, rhs, px


def mgk_direct(g1: Graph, g2: Graph, vertex_kernel, edge_kernel) -> float:
    """Direct dense solve of paper Eq. 15."""
    Lx, rhs, px = product_matrix(g1, g2, vertex_kernel, edge_kernel)
    y = np.linalg.solve(Lx, rhs)
    return float(px @ y)


def mgk_walk_sum(g1: Graph, g2: Graph, vertex_kernel, edge_kernel,
                 max_len: int = 200) -> float:
    """Fixed-point iteration of paper Eq. (9), truncated at ``max_len``.

    r_{k+1} = q_x + (P_x .* E_x) V_x r_k, K = p_x^T V_x r_inf,
    with P = D^{-1} A the transition matrix. Independent of Eq. (15)'s
    symmetrized form, so it validates the derivation chain.
    """
    d1, d2 = g1.degrees(), g2.degrees()
    P1 = g1.adjacency / d1[:, None]
    P2 = g2.adjacency / d2[:, None]
    Px = np.kron(P1, P2)
    E1 = np.repeat(np.repeat(g1.edge_labels, g2.n_nodes, 0), g2.n_nodes, 1)
    E2 = np.tile(g2.edge_labels, (g1.n_nodes, g1.n_nodes))
    Ex = _kappa_np(edge_kernel, E1, E2)
    vx = _kappa_np(vertex_kernel,
                   np.repeat(g1.vertex_labels, g2.n_nodes),
                   np.tile(g2.vertex_labels, g1.n_nodes))
    qx = np.kron(g1.stop_prob, g2.stop_prob)
    px = np.kron(g1.start_prob, g2.start_prob)
    T = (Px * Ex) * vx[None, :]
    r = qx.copy()
    for _ in range(max_len):
        r = qx + T @ r
    return float(px @ (vx * r))
