"""Core marginalized-graph-kernel library (the paper's contribution).

Public surface:
  Graph / GraphBatch        graph containers (host / device)
  base kernels              Constant, KroneckerDelta, SquareExponential, ...
  octile_decompose          two-level sparse tile storage
  rcm_order / pbr_order / morton_order / best_order
  pcg_solve                 batched masked preconditioned CG
  mgk_pairs / mgk_single    the marginalized graph kernel
  mgk_*_value_and_grad      adjoint-solve hyperparameter gradients
"""
from .adjoint import (flatten_grads, kernel_theta,
                      mgk_adaptive_value_and_grad,
                      mgk_pairs_sparse_value_and_grad,
                      mgk_pairs_value_and_grad, mgk_value_fn)
from .base_kernels import (BaseKernel, CompactPolynomial, Constant,
                           KroneckerDelta, ParamDerivative,
                           SquareExponential, pack_theta, unpack_theta)
from .graph import Graph, GraphBatch, batch_from_graphs, pad_graphs
from .mgk import MGKResult, ProductSystem, adaptive_route, \
    build_product_system, mgk_adaptive, mgk_pairs, mgk_pairs_sparse, \
    mgk_pairs_sparse_segmented, mgk_single
from .octile import (OctileSet, count_nonempty_tiles, expand_octiles,
                     feature_operands, octile_decompose,
                     tile_occupancy_histogram)
from .pcg import PCGResult, adjoint_solve, pcg_solve, \
    pcg_solve_segmented
from .precond import (KronFactors, kron_apply, kron_apply_gram,
                      kron_factor_arrays, kron_factors, kron_scalars,
                      stack_kron_factors, take_kron_factors)
from .reorder import best_order, morton_order, pbr_order, rcm_order

__all__ = [
    "BaseKernel", "CompactPolynomial", "Constant", "KroneckerDelta",
    "SquareExponential", "ParamDerivative", "pack_theta", "unpack_theta",
    "Graph", "GraphBatch", "batch_from_graphs",
    "pad_graphs", "MGKResult", "ProductSystem", "build_product_system",
    "mgk_pairs", "mgk_single", "mgk_pairs_sparse",
    "mgk_pairs_sparse_segmented", "mgk_adaptive",
    "adaptive_route", "OctileSet", "count_nonempty_tiles",
    "expand_octiles", "octile_decompose", "tile_occupancy_histogram",
    "feature_operands", "PCGResult", "pcg_solve", "pcg_solve_segmented", "adjoint_solve",
    "KronFactors", "kron_factors", "kron_factor_arrays", "kron_scalars",
    "kron_apply", "kron_apply_gram", "take_kron_factors",
    "stack_kron_factors",
    "best_order", "morton_order", "pbr_order", "rcm_order",
    "kernel_theta", "mgk_value_fn", "mgk_pairs_value_and_grad",
    "mgk_pairs_sparse_value_and_grad", "mgk_adaptive_value_and_grad",
    "flatten_grads",
]
