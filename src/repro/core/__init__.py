"""Core marginalized-graph-kernel library (the paper's contribution).

Public surface:
  Graph / GraphBatch        graph containers (host / device)
  base kernels              Constant, KroneckerDelta, SquareExponential, ...
  octile_decompose          two-level sparse tile storage
  rcm_order / pbr_order / morton_order / best_order
  pcg_solve                 batched masked preconditioned CG
  mgk_pairs / mgk_single    the marginalized graph kernel
"""
from .base_kernels import (BaseKernel, CompactPolynomial, Constant,
                           KroneckerDelta, SquareExponential)
from .graph import Graph, GraphBatch, batch_from_graphs, pad_graphs
from .mgk import MGKResult, ProductSystem, build_product_system, mgk_pairs, \
    mgk_single
from .octile import (OctileSet, count_nonempty_tiles, expand_octiles,
                     octile_decompose, tile_occupancy_histogram)
from .pcg import PCGResult, pcg_solve
from .reorder import best_order, morton_order, pbr_order, rcm_order

__all__ = [
    "BaseKernel", "CompactPolynomial", "Constant", "KroneckerDelta",
    "SquareExponential", "Graph", "GraphBatch", "batch_from_graphs",
    "pad_graphs", "MGKResult", "ProductSystem", "build_product_system",
    "mgk_pairs", "mgk_single", "OctileSet", "count_nonempty_tiles",
    "expand_octiles", "octile_decompose", "tile_occupancy_histogram",
    "PCGResult", "pcg_solve", "best_order", "morton_order", "pbr_order",
    "rcm_order",
]
