"""Differentiable MGK: hyperparameter gradients via an adjoint PCG solve.

The paper's closing claim — kernel-based learning at scale — needs
``∂K/∂θ`` for the vertex/edge base-kernel hyperparameters and the
stopping probability ``q``. Nothing in the solver is natively
reverse-differentiable (``pcg_solve`` is a ``lax.while_loop``; the
Pallas kernels bake parameters in as static arguments), and unrolling
CG for autodiff would store every iterate. This module instead wraps
the solve in a ``jax.custom_vjp`` built on the implicit function
theorem (DESIGN.md §7):

    K = p_xᵀ x,     A(θ) x = b(θ),   A = D_x V_x^{-1} - A_x ∘ E_x

    x̄ = v̄ p_x
    Aᵀ λ = x̄                      -> ONE adjoint PCG solve; A is
                                     symmetric, so the adjoint system
                                     reuses the forward matvec closure
                                     (and Pallas kernels, and packs)
                                     unchanged (pcg.adjoint_solve)
    θ̄  = λᵀ (∂b/∂θ) - λᵀ (∂A/∂θ) x

The parameter contractions never materialize ∂A:

* vertex params and q only touch the DIAGONAL (and b): elementwise
  expressions in λ, x and the analytic ``dtheta()`` hooks of
  core/base_kernels.py.
* edge params enter through the off-diagonal ``A_x ∘ E_x``, whose
  θ-derivative has A's sparsity: ``λᵀ (∂A_x∘E_x) x`` is ONE raw XMV of
  x with kappa replaced by ∂kappa/∂θ (``ParamDerivative``) — the same
  dispatch backend as the forward solve — followed by a dot with λ. On
  the row-panel MXU path the derivative kernel
  ``∂kappa = Σ_r (∂f_r f'_r + f_r ∂f'_r)`` is a rank-2R bilinear form,
  so the contraction runs the UNCHANGED MXU kernel with slot operands
  ``[wg ; w]`` vs ``[w' ; wg']`` (the ``values_grad`` companions).

Cost: gradients w.r.t. ALL hyperparameters ≈ one extra PCG solve per
pair (the acceptance contract: exactly two solves in the jaxpr — tested
in tests/test_grad.py) plus one XMV per edge parameter.

Usage note: the factory closes the (concrete) graph batches and packs
over the custom_vjp function, so build the value function OUTSIDE any
jit trace and differentiate with respect to ``theta`` only::

    fn = mgk_value_fn(g1, g2, vk, ek, method="lowrank")
    theta = kernel_theta(vk, ek, q=0.05)
    vals, grads = jax.value_and_grad(lambda t: fn(t).sum())(theta)

Inner computations (PCG, the XMV kernels) stay jitted as always.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .base_kernels import BaseKernel, Constant, ParamDerivative
from .graph import GraphBatch
from .mgk import _make_matvec, _make_precond_apply, _make_sparse_matvec, \
    _outer_flat, adaptive_route, build_product_system, stop_prob_override
from .pcg import adjoint_solve, pcg_solve
from .xmv import xmv_lowrank_precomputed, weighted_operand_grads, \
    weighted_operands

__all__ = ["kernel_theta", "mgk_value_fn", "mgk_pairs_value_and_grad",
           "mgk_pairs_sparse_value_and_grad",
           "mgk_adaptive_value_and_grad", "flatten_grads"]


def kernel_theta(vertex_kernel: BaseKernel, edge_kernel: BaseKernel,
                 q: float | None = None) -> dict:
    """The canonical hyperparameter pytree the gradient entry points
    differentiate against: ``{"vertex": {...}, "edge": {...}[, "q"]}``
    seeded from the kernels' current (static) values. Drop keys to
    freeze groups; include ``q`` to make the stopping probability a
    learnable global scalar (it overrides both batches' ``stop_prob``
    and the degrees derived from it)."""
    theta = {"vertex": vertex_kernel.theta(), "edge": edge_kernel.theta()}
    if q is not None:
        theta["q"] = jnp.asarray(q, jnp.float32)
    return theta


def flatten_grads(grads: dict) -> dict:
    """``{"vertex": {"h": g}, "edge": {"alpha": g}, "q": g}`` ->
    ``{"vertex.h": g, "edge.alpha": g, "q": g}`` (the storage layout of
    Gram gradient blocks, distributed/gram.py)."""
    flat = {}
    for group, val in grads.items():
        if isinstance(val, dict):
            for name, g in val.items():
                flat[f"{group}.{name}"] = g
        else:
            flat[group] = val
    return flat


def mgk_value_fn(
    g1: GraphBatch,
    g2: GraphBatch,
    vertex_kernel: BaseKernel = Constant(1.0),
    edge_kernel: BaseKernel = Constant(1.0),
    *,
    method: str = "lowrank",
    packs1=None,
    packs2=None,
    sparse_mode: str = "auto",
    chunk: int = 8,
    tol: float = 1e-10,
    max_iter: int = 512,
    fixed_iters: int | None = None,
    pcg_variant: str = "classic",
    trust_pack_weights: bool = False,
    gram_tile: tuple[int, int] | None = None,
    precond: str = "jacobi",
    kron_rank: int = 2,
    precond_factors: tuple | None = None,
) -> Callable:
    """Build ``value(theta) -> [B]`` for aligned pair batches, wrapped in
    the adjoint-solve ``jax.custom_vjp``.

    ``method``: any dense backend of :func:`~repro.core.mgk.mgk_pairs`
    ("full" / "elementwise" / "lowrank" / "pallas") or "sparse" with
    stacked row-panel ``packs1``/``packs2`` (+ ``sparse_mode``, as in
    :func:`~repro.core.mgk.mgk_pairs_sparse`; the legacy TilePack packs
    carry no in-kernel theta path and are not supported here).
    ``gram_tile=(Bi, Bj)``: the packs are PER-AXIS and both the forward
    and adjoint solves — plus the edge-gradient contraction — run on the
    single-launch Gram-tile kernel (g1/g2 stay the row-major
    pair-flattened batches, as in ``mgk_pairs_sparse``).

    ``trust_pack_weights``: use the packs' host-precomputed ``values_w``
    / ``values_grad`` buffers instead of re-deriving them on device from
    ``theta`` — valid ONLY when theta's edge values equal the pack-time
    kernel parameters (the Gram driver's fixed-θ evaluation; it is what
    makes the pack cache shared between forward and adjoint solves).

    ``precond="kron"``: BOTH the forward and the adjoint solve run with
    the Kronecker-factored approximate inverse (DESIGN.md §9). The
    factors are built ONCE here from the concrete batches (or taken
    from ``precond_factors``, the Gram driver's pack-time cache) and
    the identical SPD ``M^{-1}`` closure serves both solves — the
    preconditioner shapes only the solve trajectory, so gradients and
    the exactly-two-solves jaxpr pin are untouched. The factors use the
    batches' PACK-TIME degrees: a traced ``q`` override still reaches
    the operator and the right-hand side exactly (correctness), it just
    doesn't re-derive the preconditioner statistics (iteration count
    only).

    The returned callable carries ``value_and_pair_grads(theta)``
    returning per-pair gradients (``[B]`` leaves) from the same single
    forward + adjoint solve pair.
    """
    sparse = method in ("sparse", "pallas_sparse")
    if sparse:
        from repro.kernels.ops import RowPanelPack
        if not isinstance(packs1, RowPanelPack) or \
                not isinstance(packs2, RowPanelPack):
            raise ValueError(
                "method='sparse' needs stacked RowPanelPack packs1/packs2"
                " (legacy TilePacks have no differentiable path)")
    B, n = g1.adjacency.shape[0], g1.adjacency.shape[1]
    m = g2.adjacency.shape[1]
    pf1, pf2 = precond_factors if precond_factors is not None \
        else (None, None)
    papply = _make_precond_apply(precond, g1, g2, vertex_kernel,
                                 edge_kernel, (B, n, m),
                                 gram_tile=gram_tile, factors1=pf1,
                                 factors2=pf2, kron_rank=kron_rank)
    solve_kw = dict(tol=tol, max_iter=max_iter, fixed_iters=fixed_iters,
                    variant=pcg_variant, precond_apply=papply)

    def _parts(theta):
        tv = theta.get("vertex") or None
        te = theta.get("edge") or None
        q = theta.get("q")
        return tv, te, q

    def _build_mv(theta, sys_):
        _, te, _ = _parts(theta)
        te_mv = None if trust_pack_weights else te
        if sparse:
            return _make_sparse_matvec(sys_, packs1, packs2, edge_kernel,
                                       sparse_mode, (B, n, m),
                                       theta_e=te_mv, gram_tile=gram_tile)
        return _make_matvec(g1, g2, sys_, edge_kernel, method, chunk,
                            theta_e=te_mv)

    def _system(theta):
        tv, _, q = _parts(theta)
        sys_ = build_product_system(g1, g2, vertex_kernel, theta_v=tv,
                                    q=q)
        return sys_, _build_mv(theta, sys_)

    def _solve(theta):
        sys_, mv = _system(theta)
        rhs = sys_.dx * sys_.qx
        diag = sys_.dx / sys_.vx
        sol = pcg_solve(mv, rhs, diag, **solve_kw)
        return sol, sys_, mv

    # -- the adjoint backward pass --------------------------------------
    def _edge_grads(te, x_mat, names):
        """{name: raw XMV of x with kappa -> ∂kappa/∂θ_name} for ALL
        edge parameters: the sparsity-preserving half of λᵀ (∂A/∂θ) x,
        [B, n*m] per name. Parameter-independent operand derivation
        (device_weighted_pack, weighted operands) is hoisted out of the
        per-name loop — it already carries every parameter's slice."""
        if sparse:
            have_w = packs1.values_w is not None and \
                packs2.values_w is not None
            # mirror _make_sparse_matvec: "auto" follows pack-time intent
            mxu = sparse_mode == "mxu" or (sparse_mode == "auto"
                                           and have_w)
            if mxu:
                from repro.kernels.ops import device_weighted_pack, \
                    xmv_gram_tile, xmv_row_panel_batched
                if trust_pack_weights and packs1.values_grad is not None \
                        and packs2.values_grad is not None:
                    p1, p2 = packs1, packs2
                else:
                    p1 = device_weighted_pack(packs1, edge_kernel,
                                              theta=te, with_grad=True)
                    p2 = device_weighted_pack(packs2, edge_kernel,
                                              theta=te, with_grad=True)
                out = {}
                for name in names:
                    pi = edge_kernel.param_names().index(name)
                    wg1 = jnp.take(p1.values_grad, pi, axis=-4)
                    wg2 = jnp.take(p2.values_grad, pi, axis=-4)
                    # rank-2R bilinear form: [wg ; w] vs [w' ; wg']
                    # computes Σ_r (wg_r P w'_rᵀ + w_r P wg'_rᵀ) in the
                    # SAME kernel
                    c1 = p1._replace(
                        values_w=jnp.concatenate([wg1, p1.values_w],
                                                 axis=-3),
                        values_grad=None)
                    c2 = p2._replace(
                        values_w=jnp.concatenate([p2.values_w, wg2],
                                                 axis=-3),
                        values_grad=None)
                    if gram_tile is not None:
                        Bi, Bj = gram_tile
                        y = xmv_gram_tile(
                            c1, c2, x_mat.reshape(Bi, Bj, n, m),
                            edge_kernel, mode="mxu")
                    else:
                        y = xmv_row_panel_batched(c1, c2, x_mat,
                                                  edge_kernel, mode="mxu")
                    out[name] = y.reshape(B, -1)
                return out
            x_flat = x_mat.reshape(B, -1)
            return {name: _make_sparse_matvec(
                None, packs1, packs2, ParamDerivative(edge_kernel, name),
                "elementwise", (B, n, m), theta_e=te, raw=True,
                gram_tile=gram_tile)(x_flat)
                for name in names}
        if method == "lowrank":
            wo = lambda a, e: weighted_operands(a, e, edge_kernel,  # noqa
                                                theta=te)
            dwo = lambda a, e: weighted_operand_grads(               # noqa
                a, e, edge_kernel, theta=te)
            wa = jax.vmap(wo)(g1.adjacency, g1.edge_labels)
            wap = jax.vmap(wo)(g2.adjacency, g2.edge_labels)
            dwa = jax.vmap(dwo)(g1.adjacency, g1.edge_labels)
            dwap = jax.vmap(dwo)(g2.adjacency, g2.edge_labels)
            return {name: (
                jax.vmap(xmv_lowrank_precomputed)(dwa[name], wap, x_mat)
                + jax.vmap(xmv_lowrank_precomputed)(wa, dwap[name],
                                                    x_mat)
            ).reshape(B, -1) for name in names}
        x_flat = x_mat.reshape(B, -1)
        return {name: _make_matvec(
            g1, g2, None, ParamDerivative(edge_kernel, name), method,
            chunk, theta_e=te, raw=True)(x_flat) for name in names}

    def _pair_grads(theta, x, ct, sys_, mv):
        """Per-pair hyperparameter gradients, [B] leaves mirroring
        ``theta``; ``ct`` [B] scales the adjoint right-hand side (ones
        for raw per-pair gradients, the upstream cotangent in the VJP).
        ``sys_``/``mv`` are the forward solve's product system and
        matvec closure, reused — not rebuilt — for the adjoint."""
        tv, te, q = _parts(theta)
        diag = sys_.dx / sys_.vx
        lam = adjoint_solve(mv, ct[:, None] * sys_.px, diag,
                            **solve_kw).x
        grads: dict = {}
        if "vertex" in theta:
            x1 = g1.vertex_labels[:, :, None]
            x2 = g2.vertex_labels[:, None, :]
            dv = vertex_kernel.dtheta(x1, x2, tv)
            # ∂A = diag(-dx vx^{-2} ∂vx)  =>  -λᵀ(∂A)x elementwise
            coeff = lam * x * sys_.dx / (sys_.vx * sys_.vx)
            grads["vertex"] = {
                name: jnp.sum(
                    coeff * dv[name].reshape(B, -1) * sys_.mask, axis=-1)
                for name in theta["vertex"]}
        if "edge" in theta:
            x_mat = x.reshape(B, n, m)
            # ∂A = -(A_x ∘ ∂kappa E_x)  =>  -λᵀ(∂A)x = +λᵀ XMV_∂kappa(x)
            ys = _edge_grads(te, x_mat, tuple(theta["edge"]))
            grads["edge"] = {
                name: jnp.sum(lam * ys[name], axis=-1)
                for name in theta["edge"]}
        if "q" in theta and q is None:
            grads["q"] = None
        elif "q" in theta:
            g1q = stop_prob_override(g1, q)
            g2q = stop_prob_override(g2, q)
            # ∂dx = maskx (m ⊗ d' + d ⊗ m');  qx = q² maskx
            dxq = sys_.mask * (
                _outer_flat(g1.node_mask, g2q.degrees)
                + _outer_flat(g1q.degrees, g2.node_mask))
            drhs = dxq * sys_.qx + sys_.dx * 2.0 * q * sys_.mask
            ddiag = dxq / sys_.vx
            grads["q"] = jnp.sum(lam * (drhs - x * ddiag), axis=-1)
        return grads

    @jax.custom_vjp
    def value(theta):
        sol, sys_, _ = _solve(theta)
        return jnp.sum(sys_.px * sol.x, axis=-1)

    def value_fwd(theta):
        # residuals: theta, the solution, and the product system (plain
        # arrays) — the backward pass rebuilds only the matvec closure
        sol, sys_, _ = _solve(theta)
        return jnp.sum(sys_.px * sol.x, axis=-1), (theta, sol.x, sys_)

    def value_bwd(res, ct):
        theta, x, sys_ = res
        grads = _pair_grads(theta, x, ct, sys_, _build_mv(theta, sys_))
        return (jax.tree.map(lambda a: jnp.sum(a, axis=0), grads),)

    value.defvjp(value_fwd, value_bwd)

    def value_and_pair_grads(theta, with_aux: bool = False):
        """(values [B], per-pair grads) from ONE forward + ONE adjoint
        solve sharing one system/matvec build; ``with_aux`` appends the
        forward :class:`PCGResult` (iteration counts / convergence for
        the Gram driver's block records)."""
        sol, sys_, mv = _solve(theta)
        vals = jnp.sum(sys_.px * sol.x, axis=-1)
        grads = _pair_grads(theta, sol.x, jnp.ones_like(vals), sys_, mv)
        if with_aux:
            return vals, grads, sol
        return vals, grads

    value.value_and_pair_grads = value_and_pair_grads
    return value


def mgk_pairs_value_and_grad(
    g1: GraphBatch, g2: GraphBatch, theta: dict | None = None,
    vertex_kernel: BaseKernel = Constant(1.0),
    edge_kernel: BaseKernel = Constant(1.0), **spec,
) -> tuple[jnp.ndarray, dict]:
    """(values [B], per-pair grads) for the dense backends — the
    ``value_and_grad``-style companion of ``mgk_pairs``. ``theta``
    defaults to :func:`kernel_theta` of the two kernels (no ``q``)."""
    theta = kernel_theta(vertex_kernel, edge_kernel) \
        if theta is None else theta
    fn = mgk_value_fn(g1, g2, vertex_kernel, edge_kernel, **spec)
    return fn.value_and_pair_grads(theta)


def mgk_pairs_sparse_value_and_grad(
    g1: GraphBatch, g2: GraphBatch, packs1, packs2,
    theta: dict | None = None,
    vertex_kernel: BaseKernel = Constant(1.0),
    edge_kernel: BaseKernel = Constant(1.0), **spec,
) -> tuple[jnp.ndarray, dict]:
    """Sparse (row-panel) companion of ``mgk_pairs_sparse``."""
    theta = kernel_theta(vertex_kernel, edge_kernel) \
        if theta is None else theta
    fn = mgk_value_fn(g1, g2, vertex_kernel, edge_kernel,
                      method="sparse", packs1=packs1, packs2=packs2,
                      **spec)
    return fn.value_and_pair_grads(theta)


def mgk_adaptive_value_and_grad(
    g1: GraphBatch, g2: GraphBatch,
    vertex_kernel: BaseKernel = Constant(1.0),
    edge_kernel: BaseKernel = Constant(1.0),
    theta: dict | None = None,
    *,
    q: float | None = None,
    density_threshold: float = 0.15,
    tile: int = 8,
    tol: float = 1e-10,
    max_iter: int = 512,
    fixed_iters: int | None = None,
    pcg_variant: str = "classic",
    precond: str = "jacobi",
    kron_rank: int = 2,
) -> tuple[jnp.ndarray, dict]:
    """Adaptive-dispatch companion of ``mgk_adaptive``: route through
    the :func:`~repro.core.mgk.adaptive_route` table, then compute
    (values, per-pair hyperparameter grads) with the adjoint solve on
    whichever backend the table picked. ``precond`` rides along to the
    winning backend's forward AND adjoint solves."""
    theta = kernel_theta(vertex_kernel, edge_kernel, q=q) \
        if theta is None else theta
    route, tile = adaptive_route(g1, g2, edge_kernel,
                                 density_threshold=density_threshold,
                                 tile=tile)
    kw = dict(tol=tol, max_iter=max_iter, fixed_iters=fixed_iters,
              pcg_variant=pcg_variant, precond=precond,
              kron_rank=kron_rank)
    if route.startswith("sparse"):
        from repro.kernels.ops import row_panel_packs_for_batch
        ek_pack = edge_kernel if route == "sparse_mxu" else None
        p1 = row_panel_packs_for_batch(g1, tile=tile, edge_kernel=ek_pack)
        p2 = row_panel_packs_for_batch(g2, tile=tile, edge_kernel=ek_pack)
        fn = mgk_value_fn(
            g1, g2, vertex_kernel, edge_kernel, method="sparse",
            packs1=p1, packs2=p2,
            sparse_mode="mxu" if route == "sparse_mxu" else "elementwise",
            **kw)
    else:
        fn = mgk_value_fn(g1, g2, vertex_kernel, edge_kernel,
                          method=route, **kw)
    return fn.value_and_pair_grads(theta)
