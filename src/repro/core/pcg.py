"""Batched, masked, diagonally-preconditioned conjugate gradient.

The jax.lax.while_loop port of paper Algorithm 1, generalized to solve a
whole batch of independent SPD systems in lockstep (the TPU replacement for
"one warp per graph pair"): converged systems are frozen with a mask so a
batch runs until ALL members converge (or max_iter). This is exactly the
behavior the paper's load-balancing section reasons about — iteration-count
variance across pairs — which our scheduler handles by bucketing pairs of
similar size (distributed/scheduler.py).

Two recurrences (``variant=``, DESIGN.md §3):

* ``"classic"`` — textbook PCG. Each iteration has TWO dependent
  reduction rounds: (p, Ap) must finish before x/r update, and (r, z)
  must finish before the next direction p. When the product rows are
  sharded over the "model" mesh axis (distributed/gram.py) each round is
  a cross-device all-reduce, so latency enters the critical path twice
  per iteration.
* ``"pipelined"`` — single-reduction pipelined PCG in the
  Chronopoulos–Gear form used by the pipelined-CG literature (Ghysels &
  Vanroose; Tiwari & Vadhiyar, PAPERS.md): s = A p is obtained by
  recurrence (computed once, not re-derived from p), and ALL inner
  products of an iteration — gamma = (r, u), delta = (w, u), and the
  convergence check (r, r) — are issued together as ONE fused reduction
  round. Same solution trajectory in exact arithmetic; one reduction
  latency per iteration instead of two. Unlike the fully-recurred
  Ghysels–Vanroose variant, u = M^{-1} r and w = A u stay freshly
  computed, so f32 attainable accuracy matches classic PCG.

Both variants are written as (init, body) *machines* over a per-pair
state dict whose every leaf carries the leading batch axis. The lockstep
solver (:func:`pcg_solve`) runs a machine under ``while_loop``/``scan``;
:func:`pcg_solve_segmented` runs the SAME body in fixed-size segments
and, between segments, compacts the live-pair set so converged pairs
drop out of the matvec batch entirely (gather/scatter remap) instead of
riding along masked to ``max_iter`` (DESIGN.md §8). Because every
recurrence and reduction is per-pair, the compacted trajectory is
iterate-for-iterate identical to masked lockstep.

Preconditioning (``precond_apply``, DESIGN.md §9): the machines apply
``M^{-1}`` through one hook — ``z = apply(diag, r)`` — which defaults
to the paper's Jacobi ``r / diag`` and accepts any SPD application
(the Kronecker-factored approximate inverse of ``core/precond.py``).
Convergence is declared on the PRECONDITIONED residual norm

    (r, M^{-1} r) <= tol² · (b, M^{-1} b)

identically in every variant and in both the lockstep and segmented
solvers: classic already computes ``rho = (r, z)`` and pipelined
``gamma = (r, u)`` — the SAME quantity — so the criterion costs no
extra reduction (it previously burned one on ``(r, r)``) and cannot
drift between recurrences or between ``precond=`` choices.

Numerical guards (``guard=``, DESIGN.md §10). At Gram-build scale
(~5·10⁹ pair solves) ill-conditioned systems, a failed preconditioner
SPD certificate, and transient data corruption are certainties, and an
unguarded lockstep batch silently turns one poisoned pair into NaN Gram
entries. With a :class:`GuardSpec` (the default) every iteration
additionally watches, PER PAIR, the scalars it already computes:

* **non-finite** — NaN/Inf in the reduction scalars ((p, Ap) and
  (r, z) for classic; (r, u) and (w, u) for pipelined), which any
  NaN/Inf anywhere in the matvec output or iterates reaches within one
  reduction;
* **breakdown** — a non-positive curvature (p, Ap) <= 0 or negative
  preconditioned residual (r, M^{-1} r) < 0: the operator or the
  M^{-1} application is not SPD along the current direction (the §9.2
  certificate failed, or rounding destroyed conjugacy);
* **divergence** — the criterion quantity exceeds
  ``divergence_factor`` times its running minimum;
* **stagnation** — no new running minimum for ``stagnation_window``
  consecutive iterations (pipelined recurrence drift: the recurred
  s = A p leaves the true residual — the classic failure mode of
  pipelined CG the residual-replacement literature addresses).

A flagged pair gets a bounded RESTART with residual replacement: the
true residual ``r = b - A x`` is recomputed from the (finite part of
the) current iterate, the direction set is rebuilt from ``M^{-1} r``,
and the pair continues (status gains ``PCG_RESTARTED`` plus the cause
flag). The recovery matvec runs under a batch-wide ``lax.cond``, so the
clean hot path pays only a handful of [B]-scalar comparisons per
iteration (<5% — measured by ``benchmarks/faults_bench.py``). After
``max_restarts`` the pair is frozen DEAD: it stops iterating (and, in
the segmented solver, retires from the matvec batch), keeps its cause
flags, and surfaces through ``PCGResult.status`` for the driver's
degradation ladder to escalate or quarantine — never a silent NaN.
``fault=`` (a :class:`MatvecFault`) is the deterministic corruption
seam the fault-injection harness (distributed/faults.py) uses to test
exactly this machinery; it compiles away when None.

Differentiability: the dynamic ``while_loop`` body is NOT reverse-mode
differentiable, and unrolling the iteration for autodiff would store
every iterate. Gradients of solutions therefore go through the implicit
function theorem instead — ``x̄ -> λ`` with ``Aᵀ λ = x̄`` — which for the
MGK's SYMMETRIC generalized Laplacian is just a second ``pcg_solve``
with the *identical* matvec closure (:func:`adjoint_solve`). The
``jax.custom_vjp`` that packages this lives in ``core/adjoint.py``
(DESIGN.md §7); this module stays a plain primal solver.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["PCGResult", "pcg_solve", "pcg_solve_segmented",
           "adjoint_solve", "GuardSpec", "MatvecFault",
           "PCG_OK", "PCG_MAX_ITER", "PCG_BREAKDOWN", "PCG_NONFINITE",
           "PCG_STAGNATION", "PCG_DIVERGENCE", "PCG_RESTARTED",
           "status_names"]


# -- per-pair status flags (DESIGN.md §10) -----------------------------------
#
# A bitmask, not an enum: one pair can legitimately carry several flags
# (e.g. RESTARTED|NONFINITE for a transient matvec NaN the restart
# recovered from, with converged=True). MAX_ITER is set by _result only
# when no failure cause is recorded — a dead pair reports its cause, a
# merely-slow pair reports MAX_ITER, and the two stay distinguishable
# all the way up through MGKResult into the Gram driver's manifest.
PCG_OK = 0            # converged, no anomaly observed
PCG_MAX_ITER = 1      # hit the iteration cap without converging
PCG_BREAKDOWN = 2     # non-SPD curvature: (p, Ap) <= 0 or (r, M⁻¹r) < 0
PCG_NONFINITE = 4     # NaN/Inf reached the reduction scalars
PCG_STAGNATION = 8    # no residual improvement for a whole window
PCG_DIVERGENCE = 16   # residual blew past divergence_factor × best
PCG_RESTARTED = 32    # at least one residual-replacement restart ran

_PCG_CAUSES = (PCG_BREAKDOWN | PCG_NONFINITE | PCG_STAGNATION
               | PCG_DIVERGENCE)
_STATUS_NAMES = ((PCG_MAX_ITER, "max_iter"), (PCG_BREAKDOWN, "breakdown"),
                 (PCG_NONFINITE, "nonfinite"),
                 (PCG_STAGNATION, "stagnation"),
                 (PCG_DIVERGENCE, "divergence"),
                 (PCG_RESTARTED, "restarted"))


def status_names(status: int) -> list[str]:
    """Human-readable flag names of one status word (["ok"] for 0)."""
    s = int(status)
    names = [name for bit, name in _STATUS_NAMES if s & bit]
    return names or ["ok"]


class GuardSpec(NamedTuple):
    """Static guard configuration (hashable — rides jit static args).

    max_restarts: residual-replacement restarts per pair before it is
      frozen dead; each restart costs one recovery matvec (two for the
      pipelined variant, which also rebuilds w = A u).
    stagnation_window: consecutive iterations without a new running
      minimum of the convergence criterion before a restart is forced.
      Healthy solves on this codebase converge in tens of iterations,
      so the default never fires on them.
    divergence_factor: restart when the criterion exceeds this multiple
      of its running minimum.
    """
    max_restarts: int = 2
    stagnation_window: int = 64
    divergence_factor: float = 1e4


class MatvecFault(NamedTuple):
    """Deterministic matvec-output corruption — the solver's
    fault-injection seam (distributed/faults.py, DESIGN.md §10).

    Applied INSIDE the machine bodies to the matvec result, so the
    guards face exactly what a corrupted kernel output would look like.
    ``pairs`` are batch-lane indices; the fault fires while a lane's
    own iteration counter is in ``[start, stop)`` (``stop=None`` =
    persistent). Being a NamedTuple of hashables it rides jit static
    args, so arming/disarming a fault retraces instead of silently
    reusing a clean cached trace.

    Under :func:`pcg_solve_segmented`, lane indices refer to the
    CURRENT (possibly compacted) batch — faults meant for specific
    pairs should finish (``stop``) within the first segment, before any
    retirement remap.
    """
    pairs: tuple[int, ...]
    start: int = 0
    stop: int | None = 1
    value: float = float("nan")

    def apply(self, y: jnp.ndarray, iters: jnp.ndarray) -> jnp.ndarray:
        idx = jnp.asarray(self.pairs, dtype=jnp.int32)
        lane = jnp.zeros((y.shape[0],), bool).at[idx].set(
            True, mode="drop")
        hit = iters >= self.start
        if self.stop is not None:
            hit = jnp.logical_and(hit, iters < self.stop)
        bad = jnp.logical_and(lane, hit)
        return jnp.where(bad[:, None], jnp.full_like(y, self.value), y)


def _apply_fault(fault, y, iters):
    return y if fault is None else fault.apply(y, iters)


def _resolve_guard(guard) -> GuardSpec | None:
    if guard is None or guard is False:
        return None
    if guard is True:
        return GuardSpec()
    if isinstance(guard, GuardSpec):
        return guard
    raise TypeError(f"guard must be bool/None/GuardSpec, got {guard!r}")


def _guard(x):
    """Divide-safe denominator (0 -> 1; the numerator is 0 there too)."""
    return jnp.where(x == 0, jnp.asarray(1.0, x.dtype), x)


def _jacobi_apply(diag, r):
    """The default preconditioner application (paper Alg. 1 line 2)."""
    return r / diag


def _wrap_apply(precond_apply):
    """Adapt the public ``precond_apply`` hook (r -> M^{-1} r, or None
    for Jacobi) to the machines' internal ``apply(diag, r)`` signature —
    the ONE adapter shared by the lockstep and segmented solvers."""
    if precond_apply is None:
        return _jacobi_apply
    return lambda diag, r: precond_apply(r)


# -- the two recurrence machines ---------------------------------------------
#
# state: dict of per-pair arrays (EVERY leaf has the leading [B] axis, so
# a gather/scatter remap of the batch is a tree_map) holding the iterates
# plus the per-pair constants (diag preconditioner, convergence
# threshold). body(matvec, apply, state) advances one masked iteration;
# converged pairs are frozen, so running extra masked iterations — or
# running a pair in a different batch composition — never changes its
# trajectory (the segmented-solver contract). ``apply(diag, r)`` is the
# M^{-1} application; convergence is declared on (r, M^{-1} r), which
# both machines already compute (classic: rho; pipelined: gamma), so
# the criterion is the IDENTICAL quantity in every variant under every
# preconditioner — the tolerance-semantics contract of DESIGN.md §9.
#
# Under a GuardSpec the state grows the guard fields (b, status,
# restarts, best, stall, dead) and each body runs _guard_step after its
# recurrence: detection on the scalars the iteration already computed,
# restart under a batch-wide lax.cond. A clean iteration's TRAJECTORY is
# bit-identical with guards on or off — the guards only observe until
# something trips.

def _precond_thresh(rho0, tol):
    eps = jnp.asarray(1e-30, rho0.dtype)
    return (tol * tol) * jnp.maximum(rho0, eps)


def _halt(st):
    """Pairs that must stop iterating: converged, or frozen dead by the
    guard after exhausting restarts."""
    dead = st.get("dead")
    return st["conv"] if dead is None else jnp.logical_or(st["conv"],
                                                          dead)


def _guard_init(st, b, guard):
    if guard is None:
        return st
    B = b.shape[0]
    st.update(
        b=b,                                    # RHS, kept for r = b - Ax
        status=jnp.zeros(B, jnp.int32),
        restarts=jnp.zeros(B, jnp.int32),
        best=st["res"],                         # running criterion min
        stall=jnp.zeros(B, jnp.int32),          # iters since last min
        dead=jnp.zeros(B, bool))
    return st


def _guard_step(matvec, apply_mz, fault, guard, st, nxt, active,
                nonfinite, breakdown, make_repl, make_zeros):
    """Shared guard pass run after a machine body (DESIGN.md §10).

    ``nxt`` is the body's freshly-computed state, ``active`` the mask it
    iterated under, ``nonfinite``/``breakdown`` the [B] detection bits
    from the body's own reduction scalars. ``make_repl(x_safe)`` builds
    the variant's residual-replacement state (one or two recovery
    matvecs — only traced into the taken branch of a batch-wide
    lax.cond); ``make_zeros()`` its zero-cost skip-branch twin."""
    res_new, thresh = nxt["res"], nxt["thresh"]
    nonfinite = jnp.logical_and(active, nonfinite)
    breakdown = jnp.logical_and(active,
                                jnp.logical_and(breakdown, ~nonfinite))
    best, stall = st["best"], st["stall"]
    diverged = jnp.logical_and(
        active, res_new > guard.divergence_factor * best)
    improved = res_new < best
    stall = jnp.where(jnp.logical_and(active, ~improved), stall + 1,
                      jnp.zeros_like(stall))
    stagnated = jnp.logical_and(active, stall >= guard.stagnation_window)
    best = jnp.where(jnp.logical_and(active, improved), res_new, best)

    trigger = nonfinite | breakdown | diverged | stagnated
    can = st["restarts"] < guard.max_restarts
    do_restart = jnp.logical_and(trigger, can)
    new_dead = jnp.logical_and(trigger, ~can)
    flag = functools.partial(jnp.where, size=None) if False else None
    del flag
    z32 = jnp.int32(0)
    status = (st["status"]
              | jnp.where(nonfinite, jnp.int32(PCG_NONFINITE), z32)
              | jnp.where(breakdown, jnp.int32(PCG_BREAKDOWN), z32)
              | jnp.where(diverged, jnp.int32(PCG_DIVERGENCE), z32)
              | jnp.where(stagnated, jnp.int32(PCG_STAGNATION), z32)
              | jnp.where(do_restart, jnp.int32(PCG_RESTARTED), z32))

    def _replace(_):
        x = nxt["x"]
        x_ok = jnp.all(jnp.isfinite(x), axis=-1)
        x_safe = jnp.where(x_ok[:, None], x, jnp.zeros_like(x))
        return make_repl(x_safe)

    repl = jax.lax.cond(jnp.any(do_restart), _replace,
                        lambda _: make_zeros(), None)
    out = dict(nxt)
    sel = do_restart
    for k, v in repl.items():
        if k == "conv_now":
            continue
        m = sel[:, None] if v.ndim == 2 else sel
        out[k] = jnp.where(m, v, out[k])
    # residual replacement can reveal true convergence on the spot
    out["conv"] = jnp.logical_or(out["conv"],
                                 jnp.logical_and(sel, repl["conv_now"]))
    dead = jnp.logical_or(st["dead"], new_dead)
    # pipelined scalars feed UNMASKED vector updates next iteration —
    # a dead pair must never leave a NaN alpha/beta behind
    for k in ("alpha", "beta"):
        if k in out:
            out[k] = jnp.where(dead, jnp.zeros_like(out[k]), out[k])
    out.update(
        b=st["b"], dead=dead, status=status,
        restarts=st["restarts"] + sel.astype(jnp.int32),
        best=jnp.where(sel, repl["res"], best),
        stall=jnp.where(sel, jnp.zeros_like(stall), stall))
    return out


def _classic_init(matvec, apply_mz, b, diag_precond, tol, guard=None,
                  fault=None):
    del matvec, fault  # classic needs no setup matvec
    r0 = b
    z0 = apply_mz(diag_precond, r0)
    rho0 = jnp.sum(r0 * z0, axis=-1)       # (b, M^{-1} b)
    thresh = _precond_thresh(rho0, tol)
    st = dict(
        x=jnp.zeros_like(b), r=r0, p=z0,
        rho=rho0,
        conv=rho0 <= thresh, res=rho0,
        iters=jnp.zeros(b.shape[0], jnp.int32),
        diag=diag_precond, thresh=thresh)
    return _guard_init(st, b, guard)


def _classic_body(matvec, apply_mz, st, guard=None, fault=None):
    x, r, p, rho = st["x"], st["r"], st["p"], st["rho"]
    conv, res, thresh = st["conv"], st["res"], st["thresh"]
    active = ~_halt(st)
    a = matvec(p)                                       # [B, N]
    a = _apply_fault(fault, a, st["iters"])
    pa = jnp.sum(p * a, axis=-1)
    alpha = jnp.where(active, rho / _guard(pa), 0.0)
    x = x + alpha[:, None] * p
    r = r - alpha[:, None] * a
    z = apply_mz(st["diag"], r)
    rho_new = jnp.sum(r * z, axis=-1)
    beta = jnp.where(active, rho_new / _guard(rho), 0.0)
    p = jnp.where(active[:, None], z + beta[:, None] * p, p)
    res_new = jnp.where(active, rho_new, res)
    conv = jnp.logical_or(conv, res_new <= thresh)
    iters = st["iters"] + active.astype(jnp.int32)
    nxt = dict(
        x=x, r=r, p=p, rho=jnp.where(active, rho_new, rho),
        conv=conv, res=res_new, iters=iters,
        diag=st["diag"], thresh=thresh)
    if guard is None:
        return nxt

    def make_repl(x_safe):
        ax = _apply_fault(fault, matvec(x_safe), iters)
        r_r = st["b"] - ax
        z_r = apply_mz(st["diag"], r_r)
        rho_r = jnp.sum(r_r * z_r, axis=-1)
        return dict(x=x_safe, r=r_r, p=z_r, rho=rho_r, res=rho_r,
                    conv_now=rho_r <= thresh)

    def make_zeros():
        zv = jnp.zeros_like(x)
        zs = jnp.zeros_like(rho)
        return dict(x=zv, r=zv, p=zv, rho=zs, res=zs,
                    conv_now=jnp.zeros(zs.shape, bool))

    return _guard_step(
        matvec, apply_mz, fault, guard, st, nxt, active,
        nonfinite=~jnp.isfinite(pa) | ~jnp.isfinite(rho_new),
        breakdown=(pa <= 0) | (rho_new < 0),
        make_repl=make_repl, make_zeros=make_zeros)


def _pipelined_init(matvec, apply_mz, b, diag_precond, tol, guard=None,
                    fault=None):
    """Chronopoulos–Gear setup: ONE matvec (w0 = A u0)."""
    r0 = b
    u0 = apply_mz(diag_precond, r0)
    w0 = _apply_fault(fault, matvec(u0),
                      jnp.zeros(b.shape[0], jnp.int32))
    gamma0 = jnp.sum(r0 * u0, axis=-1)     # (b, M^{-1} b)
    delta0 = jnp.sum(w0 * u0, axis=-1)
    thresh = _precond_thresh(gamma0, tol)
    conv0 = gamma0 <= thresh
    zeros = jnp.zeros_like(b)
    st = dict(
        x=jnp.zeros_like(b), r=r0, u=u0, w=w0, p=zeros, s=zeros,
        gamma=gamma0,
        alpha=jnp.where(conv0, 0.0, gamma0 / _guard(delta0)),
        beta=jnp.zeros_like(gamma0),
        conv=conv0, res=gamma0,
        iters=jnp.zeros(b.shape[0], jnp.int32),
        diag=diag_precond, thresh=thresh)
    return _guard_init(st, b, guard)


def _pipelined_body(matvec, apply_mz, st, guard=None, fault=None):
    """Single-reduction (Chronopoulos–Gear) pipelined PCG iteration.

    Per iteration — ONE matvec, ONE fused reduction round:

        p <- u + beta p;   s <- w + beta s        # s = A p by recurrence
        x <- x + alpha p;  r <- r - alpha s
        u = M^{-1} r;      w = A u                # the iteration's matvec
        gamma' = (r, u);  delta = (w, u)          # fused round
        beta'  = gamma' / gamma
        alpha' = gamma' / (delta - beta' * gamma' / alpha)

    alpha is derived from the SAME reduction round as gamma (the classic
    recurrence would need (p, A p), a second, dependent round). The
    convergence check reads gamma' = (r, M^{-1} r) — the classic body's
    rho, post-update — so iteration counts match classic to the
    floating-point drift of the s-recurrence (±1 in practice), and the
    criterion needs no extra (r, r) reduction.
    """
    x, r, u, w = st["x"], st["r"], st["u"], st["w"]
    p, s = st["p"], st["s"]
    gamma, alpha, beta = st["gamma"], st["alpha"], st["beta"]
    conv, res, thresh = st["conv"], st["res"], st["thresh"]
    halted = _halt(st)
    active = ~halted
    am = active[:, None]
    # -- vector updates from the PREVIOUS round's scalars -----------
    p = jnp.where(am, u + beta[:, None] * p, p)
    s = jnp.where(am, w + beta[:, None] * s, s)   # s = A p, recurred
    x = x + alpha[:, None] * p
    r = r - alpha[:, None] * s
    u = jnp.where(am, apply_mz(st["diag"], r), u)
    mv = _apply_fault(fault, matvec(u), st["iters"])
    w = jnp.where(am, mv, w)                      # single matvec
    # -- the single fused reduction round ---------------------------
    gamma_new = jnp.sum(r * u, axis=-1)
    delta = jnp.sum(w * u, axis=-1)
    res_new = jnp.where(active, gamma_new, res)
    conv = jnp.logical_or(conv, res_new <= thresh)
    still = ~conv if guard is None else \
        ~jnp.logical_or(conv, st["dead"])
    beta = jnp.where(still, gamma_new / _guard(gamma), 0.0)
    alpha = jnp.where(
        still,
        gamma_new / _guard(delta - beta * gamma_new / _guard(alpha)),
        0.0)
    iters = st["iters"] + active.astype(jnp.int32)
    nxt = dict(
        x=x, r=r, u=u, w=w, p=p, s=s,
        gamma=jnp.where(still, gamma_new, gamma), alpha=alpha, beta=beta,
        conv=conv, res=res_new, iters=iters,
        diag=st["diag"], thresh=thresh)
    if guard is None:
        return nxt

    def make_repl(x_safe):
        # full Chronopoulos–Gear re-init from the replaced residual —
        # TWO recovery matvecs (r = b - A x, then w = A u)
        ax = _apply_fault(fault, matvec(x_safe), iters)
        r_r = st["b"] - ax
        u_r = apply_mz(st["diag"], r_r)
        w_r = _apply_fault(fault, matvec(u_r), iters)
        gamma_r = jnp.sum(r_r * u_r, axis=-1)
        delta_r = jnp.sum(w_r * u_r, axis=-1)
        conv_now = gamma_r <= thresh
        zeros = jnp.zeros_like(x_safe)
        return dict(
            x=x_safe, r=r_r, u=u_r, w=w_r, p=zeros, s=zeros,
            gamma=gamma_r,
            alpha=jnp.where(conv_now, 0.0, gamma_r / _guard(delta_r)),
            beta=jnp.zeros_like(gamma_r),
            res=gamma_r, conv_now=conv_now)

    def make_zeros():
        zv = jnp.zeros_like(x)
        zs = jnp.zeros_like(gamma)
        return dict(x=zv, r=zv, u=zv, w=zv, p=zv, s=zv, gamma=zs,
                    alpha=zs, beta=zs, res=zs,
                    conv_now=jnp.zeros(zs.shape, bool))

    return _guard_step(
        matvec, apply_mz, fault, guard, st, nxt, active,
        nonfinite=~jnp.isfinite(gamma_new) | ~jnp.isfinite(delta),
        breakdown=(gamma_new < 0) | (delta <= 0),
        make_repl=make_repl, make_zeros=make_zeros)


_MACHINES = {"classic": (_classic_init, _classic_body),
             "pipelined": (_pipelined_init, _pipelined_body)}
_SETUP_MATVECS = {"classic": 0, "pipelined": 1}


def _machine(variant: str):
    try:
        return _MACHINES[variant]
    except KeyError:
        raise ValueError(f"unknown PCG variant {variant!r}") from None


class PCGResult(NamedTuple):
    x: jnp.ndarray           # [B, N] solution
    iterations: jnp.ndarray  # [B] int32 iterations to convergence
    residual: jnp.ndarray    # [B] final (r, M^{-1} r) — the criterion
    converged: jnp.ndarray   # [B] bool
    # scalar int32: total pair-matvec evaluations the solve performed
    # (lockstep: B per iteration run; segmented: live pairs only). The
    # Gram driver feeds this — with the per-pair ``iterations`` — back
    # into bucket/cost planning (distributed/scheduler.py).
    matvec_pairs: jnp.ndarray | None = None
    # [B] int32 PCG_* status bitmask (DESIGN.md §10). 0 = clean
    # convergence; MAX_ITER = slow but sane; any cause flag
    # (BREAKDOWN/NONFINITE/STAGNATION/DIVERGENCE) = the guard froze or
    # restarted the pair — the driver's degradation-ladder signal.
    status: jnp.ndarray | None = None


def _result(st, matvec_pairs=None) -> PCGResult:
    conv = st["conv"]
    if "status" in st:
        status = st["status"]
        # MAX_ITER only when no cause flag explains the non-convergence
        unexplained = jnp.logical_and(~conv,
                                      (status & _PCG_CAUSES) == 0)
        status = status | jnp.where(unexplained, jnp.int32(PCG_MAX_ITER),
                                    jnp.int32(0))
    else:
        status = jnp.where(conv, jnp.int32(PCG_OK),
                           jnp.int32(PCG_MAX_ITER))
    return PCGResult(x=st["x"], iterations=st["iters"],
                     residual=st["res"], converged=conv,
                     matvec_pairs=matvec_pairs, status=status)


def pcg_solve(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    b: jnp.ndarray,
    diag_precond: jnp.ndarray,
    *,
    tol: float = 1e-10,
    max_iter: int = 256,
    fixed_iters: int | None = None,
    variant: str = "classic",
    precond_apply: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
    guard: GuardSpec | bool | None = True,
    fault: MatvecFault | None = None,
) -> PCGResult:
    """Solve ``A x = b`` for a batch of SPD systems (masked lockstep).

    Args:
      matvec: function mapping [B, N] -> [B, N], applying each system's
        operator to its vector (the on-the-fly XMV plus diagonal terms).
      b: [B, N] right-hand sides.
      diag_precond: [B, N] the diagonal preconditioner M (paper Alg. 1
        line 2); entries must be > 0. Padded entries should be 1.
      tol: relative tolerance; system b is converged when
        (r, M^{-1} r) <= tol^2 * (b, M^{-1} b) — the preconditioned
        residual criterion, identical across variants and solvers for
        any preconditioner (DESIGN.md §9).
      max_iter: iteration cap (a safety net; the paper's systems are
        strongly diagonally dominant and converge in tens of iterations).
      fixed_iters: if set, run EXACTLY this many iterations as a
        known-trip-count scan instead of a dynamic while loop. Production
        batches use this (uniform step count across a bucket — the paper's
        load-balancing premise) and it makes the CG body visible to the
        static roofline profile (analysis/hlo_cost.py multiplies scan
        bodies by their trip count; a dynamic while reports trip=1).
      variant: "classic" (two dependent reduction rounds per iteration) or
        "pipelined" (Ghysels–Vanroose: one fused reduction round that
        overlaps the matvec — see module docstring). Identical iterates in
        exact arithmetic.
      precond_apply: optional ``z = M^{-1} r`` application ([B, N] ->
        [B, N]) replacing the Jacobi ``r / diag_precond`` — the
        Kronecker-factored approximate inverse of ``core/precond.py``
        plugs in here. Must be SPD; the same closure serves the adjoint
        solve (core/adjoint.py reuses it verbatim).
      guard: numerical guards + bounded restart (module docstring /
        DESIGN.md §10). True (default) = :class:`GuardSpec` defaults,
        False/None = the bare machines (no status tracking beyond
        MAX_ITER, no detection — the clean-path-overhead baseline of
        ``benchmarks/faults_bench.py``), or an explicit GuardSpec.
        Clean trajectories are bit-identical either way.
      fault: optional :class:`MatvecFault` corruption seam (tests /
        fault-injection harness only). Compiles away when None.

    The result's ``matvec_pairs`` records B x (iterations run + setup
    matvecs) — the lockstep cost that :func:`pcg_solve_segmented` beats
    by retiring converged pairs at segment boundaries. Guard-restart
    recovery matvecs (rare, cond-gated) are not counted.
    """
    init, body = _machine(variant)
    gspec = _resolve_guard(guard)
    apply_mz = _wrap_apply(precond_apply)
    st0 = init(matvec, apply_mz, b, diag_precond, tol, guard=gspec,
               fault=fault)
    step = functools.partial(body, matvec, apply_mz, guard=gspec,
                             fault=fault)
    if fixed_iters is not None:
        def scan_body(s, _):
            return step(s), None
        st, _ = jax.lax.scan(scan_body, st0, None, length=fixed_iters)
        it = jnp.int32(fixed_iters)
    else:
        def cond(carry):
            s, it = carry
            return jnp.logical_and(it < max_iter, ~jnp.all(_halt(s)))

        def wbody(carry):
            s, it = carry
            return step(s), it + 1

        st, it = jax.lax.while_loop(cond, wbody, (st0, jnp.int32(0)))
    B = b.shape[0]
    pairs = B * (it + _SETUP_MATVECS[variant])
    return _result(st, matvec_pairs=pairs)


def pcg_solve_segmented(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    b: jnp.ndarray,
    diag_precond: jnp.ndarray,
    *,
    tol: float = 1e-10,
    max_iter: int = 256,
    segment_size: int = 32,
    variant: str = "classic",
    select: Callable[[np.ndarray],
                     Callable[[jnp.ndarray], jnp.ndarray]] | None = None,
    pad_multiple: int = 1,
    precond_apply: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
    guard: GuardSpec | bool | None = True,
    fault: MatvecFault | None = None,
) -> PCGResult:
    """Convergence-segmented PCG with pair retirement (DESIGN.md §8).

    Runs the lockstep body in segments of at most ``segment_size``
    masked iterations (each one compiled bounded loop, early-exiting
    when every live pair has converged). Between segments
    the live-pair index set is compacted on the host: pairs that
    converged during the segment RETIRE — their state is scattered back
    into the full-batch result and they drop out of the matvec batch
    entirely via a gather remap — instead of riding along masked to
    ``max_iter``. Because every recurrence and reduction of the body is
    per-pair, the compacted trajectory is iterate-for-iterate identical
    to masked lockstep; only the amount of matvec work changes
    (``matvec_pairs`` in the result counts it).

    Args (beyond :func:`pcg_solve`):
      segment_size: iterations per segment. Within a segment a converged
        pair still rides along masked (frozen); retirement happens at
        segment boundaries.
      select: ``select(indices) -> matvec`` or ``-> (matvec,
        precond_apply)`` building the operator (and, under a
        non-Jacobi preconditioner, the matching ``M^{-1}`` application)
        for a compacted sub-batch, where ``indices`` is a host int
        array of live pair indices into the original batch (the
        Gram-tile / row-panel packs — and the Kronecker preconditioner
        factors — gather along their pair axis,
        ``core/mgk.py:mgk_pairs_sparse_segmented``). Without it no
        compaction happens — segments only add early-exit checks — and
        ``matvec_pairs`` counts the full batch per iteration.
      pad_multiple: round the live-pair count up to this multiple by
        repeating the first live index (bounds jit-shape diversity; the
        duplicate lanes iterate identically and only the real lanes are
        scattered back). 1 = exact compaction.
      precond_apply: as in :func:`pcg_solve` (the full-batch
        application; compacted sub-batches take theirs from ``select``).
      guard/fault: as in :func:`pcg_solve`. Pairs the guard freezes
        DEAD retire from the matvec batch at the next segment boundary
        exactly like converged pairs — a poisoned pair stops consuming
        matvecs the moment its restart budget is spent.

    This is a HOST-DRIVEN loop (it cannot run under an enclosing jit);
    each segment itself runs as one compiled bounded loop.
    """
    init, body = _machine(variant)
    if segment_size < 1:
        raise ValueError(f"segment_size must be >= 1, got {segment_size}")
    B = b.shape[0]
    gspec = _resolve_guard(guard)
    apply_mz = _wrap_apply(precond_apply)
    full = init(matvec, apply_mz, b, diag_precond, tol, guard=gspec,
                fault=fault)
    evals = B * _SETUP_MATVECS[variant]
    live = np.arange(B)           # real live indices (no pad lanes)
    lanes = live                  # live + pad lanes, the gathered batch
    st = full                     # state of the current `lanes` batch
    mv = matvec

    def run_segment(step_body, state, k):
        # bounded loop: at most k masked iterations, early exit the
        # moment every LIVE lane converges or dies (mid-segment
        # iterations on a fully-halted live set would be pure waste)
        def cond(carry):
            s, it = carry
            return jnp.logical_and(it < k, ~jnp.all(_halt(s)))

        def wbody(carry):
            s, it = carry
            return step_body(s), it + 1

        out, it = jax.lax.while_loop(cond, wbody, (state, jnp.int32(0)))
        return out, int(it)

    done = 0
    while done < max_iter and live.size:
        if bool(np.asarray(_halt(st)).all()):
            break
        k = min(segment_size, max_iter - done)
        st, ran = run_segment(
            functools.partial(body, mv, apply_mz, guard=gspec,
                              fault=fault), st, k)
        evals += int(lanes.size) * ran
        done += ran
        if ran == 0:
            break
        # retire: scatter the REAL lanes back, re-gather the survivors
        n_real = live.size
        if lanes.size != B or not np.array_equal(lanes, np.arange(B)):
            idx = jnp.asarray(live)
            full = {f: v.at[idx].set(st[f][:n_real])
                    for f, v in full.items()}
        else:
            full = st
        halt_live = np.asarray(_halt(st))[:n_real]
        new_live = live[~halt_live]
        if new_live.size == 0:
            break
        if select is None or new_live.size == live.size:
            continue      # nothing retired (or no compaction possible)
        live = new_live
        lanes = live
        if pad_multiple > 1 and lanes.size % pad_multiple:
            n_pad = -lanes.size % pad_multiple
            lanes = np.concatenate([lanes, np.repeat(lanes[:1], n_pad)])
        gidx = jnp.asarray(lanes)
        st = {f: jnp.take(v, gidx, axis=0) for f, v in full.items()}
        sel = select(lanes)
        if isinstance(sel, tuple):
            mv, sub_apply = sel
            apply_mz = _wrap_apply(sub_apply)
        else:
            mv = sel
            if precond_apply is not None:
                # a full-batch M^{-1} closure cannot serve a compacted
                # sub-batch; fail loudly instead of on a reshape deep
                # inside the next segment
                raise ValueError(
                    "select must return (matvec, precond_apply) when a"
                    " non-Jacobi precond_apply is in use")
    return _result(full, matvec_pairs=jnp.int32(evals))


def adjoint_solve(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    cotangent: jnp.ndarray,
    diag_precond: jnp.ndarray,
    **kw,
) -> PCGResult:
    """Solve the adjoint system ``Aᵀ λ = x̄`` of a forward ``A x = b``.

    The MGK's generalized Laplacian is symmetric (paper Eq. 15), so
    ``Aᵀ = A`` and the adjoint solve IS a forward solve with the same
    matvec closure — same Pallas kernels, same packs, same
    preconditioner, same cost. This alias exists to make that reuse an
    explicit, testable contract (core/adjoint.py builds its backward
    pass on it; DESIGN.md §7) rather than a coincidence at call sites.

    Accepts every :func:`pcg_solve` keyword (tol/max_iter/fixed_iters/
    variant/guard).
    """
    return pcg_solve(matvec, cotangent, diag_precond, **kw)
