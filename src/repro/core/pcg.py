"""Batched, masked, diagonally-preconditioned conjugate gradient.

The jax.lax.while_loop port of paper Algorithm 1, generalized to solve a
whole batch of independent SPD systems in lockstep (the TPU replacement for
"one warp per graph pair"): converged systems are frozen with a mask so a
batch runs until ALL members converge (or max_iter). This is exactly the
behavior the paper's load-balancing section reasons about — iteration-count
variance across pairs — which our scheduler handles by bucketing pairs of
similar size (distributed/scheduler.py).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["PCGResult", "pcg_solve"]


class PCGResult(NamedTuple):
    x: jnp.ndarray           # [B, N] solution
    iterations: jnp.ndarray  # [B] int32 iterations to convergence
    residual: jnp.ndarray    # [B] final ||r||^2
    converged: jnp.ndarray   # [B] bool


def pcg_solve(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    b: jnp.ndarray,
    diag_precond: jnp.ndarray,
    *,
    tol: float = 1e-10,
    max_iter: int = 256,
    fixed_iters: int | None = None,
) -> PCGResult:
    """Solve ``A x = b`` for a batch of SPD systems.

    Args:
      matvec: function mapping [B, N] -> [B, N], applying each system's
        operator to its vector (the on-the-fly XMV plus diagonal terms).
      b: [B, N] right-hand sides.
      diag_precond: [B, N] the diagonal preconditioner M (paper Alg. 1
        line 2); entries must be > 0. Padded entries should be 1.
      tol: relative tolerance; system b is converged when
        ||r||^2 <= tol^2 * ||b||^2.
      max_iter: iteration cap (a safety net; the paper's systems are
        strongly diagonally dominant and converge in tens of iterations).
      fixed_iters: if set, run EXACTLY this many iterations as a
        known-trip-count scan instead of a dynamic while loop. Production
        batches use this (uniform step count across a bucket — the paper's
        load-balancing premise) and it makes the CG body visible to the
        static roofline profile (analysis/hlo_cost.py multiplies scan
        bodies by their trip count; a dynamic while reports trip=1).
    """
    eps = jnp.asarray(1e-30, b.dtype)
    b_norm2 = jnp.maximum(jnp.sum(b * b, axis=-1), eps)   # [B]
    thresh = (tol * tol) * b_norm2

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = r0 / diag_precond
    p0 = z0
    rho0 = jnp.sum(r0 * z0, axis=-1)
    res0 = jnp.sum(r0 * r0, axis=-1)
    conv0 = res0 <= thresh
    iters0 = jnp.zeros(b.shape[0], jnp.int32)

    State = tuple  # (x, r, p, rho, conv, res, it, iters)

    def cond(s: State):
        _, _, _, _, conv, _, it, _ = s
        return jnp.logical_and(it < max_iter, ~jnp.all(conv))

    def body(s: State):
        x, r, p, rho, conv, res, it, iters = s
        active = ~conv
        a = matvec(p)                                       # [B, N]
        pa = jnp.sum(p * a, axis=-1)
        alpha = jnp.where(active, rho / jnp.where(pa == 0, 1.0, pa), 0.0)
        x = x + alpha[:, None] * p
        r = r - alpha[:, None] * a
        z = r / diag_precond
        rho_new = jnp.sum(r * z, axis=-1)
        beta = jnp.where(active, rho_new / jnp.where(rho == 0, 1.0, rho),
                         0.0)
        p = jnp.where(active[:, None], z + beta[:, None] * p, p)
        res_new = jnp.where(active, jnp.sum(r * r, axis=-1), res)
        conv = jnp.logical_or(conv, res_new <= thresh)
        iters = iters + active.astype(jnp.int32)
        rho = jnp.where(active, rho_new, rho)
        return (x, r, p, rho, conv, res_new, it + 1, iters)

    init = (x0, r0, p0, rho0, conv0, res0, jnp.int32(0), iters0)
    if fixed_iters is not None:
        def scan_body(s, _):
            return body(s), None
        final, _ = jax.lax.scan(scan_body, init, None, length=fixed_iters)
        x, _, _, _, conv, res, _, iters = final
    else:
        x, _, _, _, conv, res, _, iters = jax.lax.while_loop(cond, body,
                                                             init)
    return PCGResult(x=x, iterations=iters, residual=res, converged=conv)
