"""Batched, masked, diagonally-preconditioned conjugate gradient.

The jax.lax.while_loop port of paper Algorithm 1, generalized to solve a
whole batch of independent SPD systems in lockstep (the TPU replacement for
"one warp per graph pair"): converged systems are frozen with a mask so a
batch runs until ALL members converge (or max_iter). This is exactly the
behavior the paper's load-balancing section reasons about — iteration-count
variance across pairs — which our scheduler handles by bucketing pairs of
similar size (distributed/scheduler.py).

Two recurrences (``variant=``, DESIGN.md §3):

* ``"classic"`` — textbook PCG. Each iteration has TWO dependent
  reduction rounds: (p, Ap) must finish before x/r update, and (r, z)
  must finish before the next direction p. When the product rows are
  sharded over the "model" mesh axis (distributed/gram.py) each round is
  a cross-device all-reduce, so latency enters the critical path twice
  per iteration.
* ``"pipelined"`` — single-reduction pipelined PCG in the
  Chronopoulos–Gear form used by the pipelined-CG literature (Ghysels &
  Vanroose; Tiwari & Vadhiyar, PAPERS.md): s = A p is obtained by
  recurrence (computed once, not re-derived from p), and ALL inner
  products of an iteration — gamma = (r, u), delta = (w, u), and the
  convergence check (r, r) — are issued together as ONE fused reduction
  round. Same solution trajectory in exact arithmetic; one reduction
  latency per iteration instead of two. Unlike the fully-recurred
  Ghysels–Vanroose variant, u = M^{-1} r and w = A u stay freshly
  computed, so f32 attainable accuracy matches classic PCG.

Differentiability: the dynamic ``while_loop`` body is NOT reverse-mode
differentiable, and unrolling the iteration for autodiff would store
every iterate. Gradients of solutions therefore go through the implicit
function theorem instead — ``x̄ -> λ`` with ``Aᵀ λ = x̄`` — which for the
MGK's SYMMETRIC generalized Laplacian is just a second ``pcg_solve``
with the *identical* matvec closure (:func:`adjoint_solve`). The
``jax.custom_vjp`` that packages this lives in ``core/adjoint.py``
(DESIGN.md §7); this module stays a plain primal solver.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["PCGResult", "pcg_solve", "adjoint_solve"]


class PCGResult(NamedTuple):
    x: jnp.ndarray           # [B, N] solution
    iterations: jnp.ndarray  # [B] int32 iterations to convergence
    residual: jnp.ndarray    # [B] final ||r||^2
    converged: jnp.ndarray   # [B] bool


def _run(cond, body, init, fixed_iters):
    if fixed_iters is not None:
        def scan_body(s, _):
            return body(s), None
        final, _ = jax.lax.scan(scan_body, init, None, length=fixed_iters)
        return final
    return jax.lax.while_loop(cond, body, init)


def _guard(x):
    """Divide-safe denominator (0 -> 1; the numerator is 0 there too)."""
    return jnp.where(x == 0, jnp.asarray(1.0, x.dtype), x)


def pcg_solve(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    b: jnp.ndarray,
    diag_precond: jnp.ndarray,
    *,
    tol: float = 1e-10,
    max_iter: int = 256,
    fixed_iters: int | None = None,
    variant: str = "classic",
) -> PCGResult:
    """Solve ``A x = b`` for a batch of SPD systems.

    Args:
      matvec: function mapping [B, N] -> [B, N], applying each system's
        operator to its vector (the on-the-fly XMV plus diagonal terms).
      b: [B, N] right-hand sides.
      diag_precond: [B, N] the diagonal preconditioner M (paper Alg. 1
        line 2); entries must be > 0. Padded entries should be 1.
      tol: relative tolerance; system b is converged when
        ||r||^2 <= tol^2 * ||b||^2.
      max_iter: iteration cap (a safety net; the paper's systems are
        strongly diagonally dominant and converge in tens of iterations).
      fixed_iters: if set, run EXACTLY this many iterations as a
        known-trip-count scan instead of a dynamic while loop. Production
        batches use this (uniform step count across a bucket — the paper's
        load-balancing premise) and it makes the CG body visible to the
        static roofline profile (analysis/hlo_cost.py multiplies scan
        bodies by their trip count; a dynamic while reports trip=1).
      variant: "classic" (two dependent reduction rounds per iteration) or
        "pipelined" (Ghysels–Vanroose: one fused reduction round that
        overlaps the matvec — see module docstring). Identical iterates in
        exact arithmetic.
    """
    if variant == "classic":
        return _pcg_classic(matvec, b, diag_precond, tol=tol,
                            max_iter=max_iter, fixed_iters=fixed_iters)
    if variant == "pipelined":
        return _pcg_pipelined(matvec, b, diag_precond, tol=tol,
                              max_iter=max_iter, fixed_iters=fixed_iters)
    raise ValueError(f"unknown PCG variant {variant!r}")


def adjoint_solve(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    cotangent: jnp.ndarray,
    diag_precond: jnp.ndarray,
    **kw,
) -> PCGResult:
    """Solve the adjoint system ``Aᵀ λ = x̄`` of a forward ``A x = b``.

    The MGK's generalized Laplacian is symmetric (paper Eq. 15), so
    ``Aᵀ = A`` and the adjoint solve IS a forward solve with the same
    matvec closure — same Pallas kernels, same packs, same
    preconditioner, same cost. This alias exists to make that reuse an
    explicit, testable contract (core/adjoint.py builds its backward
    pass on it; DESIGN.md §7) rather than a coincidence at call sites.

    Accepts every :func:`pcg_solve` keyword (tol/max_iter/fixed_iters/
    variant).
    """
    return pcg_solve(matvec, cotangent, diag_precond, **kw)


def _pcg_classic(matvec, b, diag_precond, *, tol, max_iter, fixed_iters):
    eps = jnp.asarray(1e-30, b.dtype)
    b_norm2 = jnp.maximum(jnp.sum(b * b, axis=-1), eps)   # [B]
    thresh = (tol * tol) * b_norm2

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = r0 / diag_precond
    p0 = z0
    rho0 = jnp.sum(r0 * z0, axis=-1)
    res0 = jnp.sum(r0 * r0, axis=-1)
    conv0 = res0 <= thresh
    iters0 = jnp.zeros(b.shape[0], jnp.int32)

    State = tuple  # (x, r, p, rho, conv, res, it, iters)

    def cond(s: State):
        _, _, _, _, conv, _, it, _ = s
        return jnp.logical_and(it < max_iter, ~jnp.all(conv))

    def body(s: State):
        x, r, p, rho, conv, res, it, iters = s
        active = ~conv
        a = matvec(p)                                       # [B, N]
        pa = jnp.sum(p * a, axis=-1)
        alpha = jnp.where(active, rho / _guard(pa), 0.0)
        x = x + alpha[:, None] * p
        r = r - alpha[:, None] * a
        z = r / diag_precond
        rho_new = jnp.sum(r * z, axis=-1)
        beta = jnp.where(active, rho_new / _guard(rho), 0.0)
        p = jnp.where(active[:, None], z + beta[:, None] * p, p)
        res_new = jnp.where(active, jnp.sum(r * r, axis=-1), res)
        conv = jnp.logical_or(conv, res_new <= thresh)
        iters = iters + active.astype(jnp.int32)
        rho = jnp.where(active, rho_new, rho)
        return (x, r, p, rho, conv, res_new, it + 1, iters)

    init = (x0, r0, p0, rho0, conv0, res0, jnp.int32(0), iters0)
    x, _, _, _, conv, res, _, iters = _run(cond, body, init, fixed_iters)
    return PCGResult(x=x, iterations=iters, residual=res, converged=conv)


def _pcg_pipelined(matvec, b, diag_precond, *, tol, max_iter, fixed_iters):
    """Single-reduction (Chronopoulos–Gear) pipelined PCG.

    Per iteration — ONE matvec, ONE fused reduction round:

        p <- u + beta p;   s <- w + beta s        # s = A p by recurrence
        x <- x + alpha p;  r <- r - alpha s
        u = M^{-1} r;      w = A u                # the iteration's matvec
        gamma' = (r, u);  delta = (w, u);  res = (r, r)   # fused round
        beta'  = gamma' / gamma
        alpha' = gamma' / (delta - beta' * gamma' / alpha)

    alpha is derived from the SAME reduction round as gamma (the classic
    recurrence would need (p, A p), a second, dependent round). The
    convergence check reads the post-update residual exactly like the
    classic body, so iteration counts match classic to the floating-point
    drift of the s-recurrence (±1 in practice).
    """
    eps = jnp.asarray(1e-30, b.dtype)
    b_norm2 = jnp.maximum(jnp.sum(b * b, axis=-1), eps)   # [B]
    thresh = (tol * tol) * b_norm2

    x0 = jnp.zeros_like(b)
    r0 = b
    u0 = r0 / diag_precond
    w0 = matvec(u0)
    gamma0 = jnp.sum(r0 * u0, axis=-1)
    delta0 = jnp.sum(w0 * u0, axis=-1)
    res0 = jnp.sum(r0 * r0, axis=-1)
    conv0 = res0 <= thresh
    alpha0 = jnp.where(conv0, 0.0, gamma0 / _guard(delta0))
    beta0 = jnp.zeros_like(gamma0)
    zeros = jnp.zeros_like(b)
    iters0 = jnp.zeros(b.shape[0], jnp.int32)

    # (x, r, u, w, p, s, gamma, alpha, beta, conv, res, it, iters)
    def cond(st):
        conv, it = st[9], st[11]
        return jnp.logical_and(it < max_iter, ~jnp.all(conv))

    def body(st):
        x, r, u, w, p, s, gamma, alpha, beta, conv, res, it, iters = st
        active = ~conv
        am = active[:, None]
        # -- vector updates from the PREVIOUS round's scalars -----------
        p = jnp.where(am, u + beta[:, None] * p, p)
        s = jnp.where(am, w + beta[:, None] * s, s)   # s = A p, recurred
        x = x + alpha[:, None] * p
        r = r - alpha[:, None] * s
        u = jnp.where(am, r / diag_precond, u)
        w = jnp.where(am, matvec(u), w)               # single matvec
        # -- the single fused reduction round ---------------------------
        gamma_new = jnp.sum(r * u, axis=-1)
        delta = jnp.sum(w * u, axis=-1)
        res_new = jnp.where(active, jnp.sum(r * r, axis=-1), res)
        conv = jnp.logical_or(conv, res_new <= thresh)
        iters = iters + active.astype(jnp.int32)
        still = ~conv
        beta = jnp.where(still, gamma_new / _guard(gamma), 0.0)
        alpha = jnp.where(
            still,
            gamma_new / _guard(delta - beta * gamma_new / _guard(alpha)),
            0.0)
        gamma = jnp.where(still, gamma_new, gamma)
        return (x, r, u, w, p, s, gamma, alpha, beta, conv, res_new,
                it + 1, iters)

    init = (x0, r0, u0, w0, zeros, zeros, gamma0, alpha0, beta0, conv0,
            res0, jnp.int32(0), iters0)
    final = _run(cond, body, init, fixed_iters)
    x, conv, res, iters = final[0], final[9], final[10], final[12]
    return PCGResult(x=x, iterations=iters, residual=res, converged=conv)
