"""Batched, masked, diagonally-preconditioned conjugate gradient.

The jax.lax.while_loop port of paper Algorithm 1, generalized to solve a
whole batch of independent SPD systems in lockstep (the TPU replacement for
"one warp per graph pair"): converged systems are frozen with a mask so a
batch runs until ALL members converge (or max_iter). This is exactly the
behavior the paper's load-balancing section reasons about — iteration-count
variance across pairs — which our scheduler handles by bucketing pairs of
similar size (distributed/scheduler.py).

Two recurrences (``variant=``, DESIGN.md §3):

* ``"classic"`` — textbook PCG. Each iteration has TWO dependent
  reduction rounds: (p, Ap) must finish before x/r update, and (r, z)
  must finish before the next direction p. When the product rows are
  sharded over the "model" mesh axis (distributed/gram.py) each round is
  a cross-device all-reduce, so latency enters the critical path twice
  per iteration.
* ``"pipelined"`` — single-reduction pipelined PCG in the
  Chronopoulos–Gear form used by the pipelined-CG literature (Ghysels &
  Vanroose; Tiwari & Vadhiyar, PAPERS.md): s = A p is obtained by
  recurrence (computed once, not re-derived from p), and ALL inner
  products of an iteration — gamma = (r, u), delta = (w, u), and the
  convergence check (r, r) — are issued together as ONE fused reduction
  round. Same solution trajectory in exact arithmetic; one reduction
  latency per iteration instead of two. Unlike the fully-recurred
  Ghysels–Vanroose variant, u = M^{-1} r and w = A u stay freshly
  computed, so f32 attainable accuracy matches classic PCG.

Both variants are written as (init, body) *machines* over a per-pair
state dict whose every leaf carries the leading batch axis. The lockstep
solver (:func:`pcg_solve`) runs a machine under ``while_loop``/``scan``;
:func:`pcg_solve_segmented` runs the SAME body in fixed-size segments
and, between segments, compacts the live-pair set so converged pairs
drop out of the matvec batch entirely (gather/scatter remap) instead of
riding along masked to ``max_iter`` (DESIGN.md §8). Because every
recurrence and reduction is per-pair, the compacted trajectory is
iterate-for-iterate identical to masked lockstep.

Preconditioning (``precond_apply``, DESIGN.md §9): the machines apply
``M^{-1}`` through one hook — ``z = apply(diag, r)`` — which defaults
to the paper's Jacobi ``r / diag`` and accepts any SPD application
(the Kronecker-factored approximate inverse of ``core/precond.py``).
Convergence is declared on the PRECONDITIONED residual norm

    (r, M^{-1} r) <= tol² · (b, M^{-1} b)

identically in every variant and in both the lockstep and segmented
solvers: classic already computes ``rho = (r, z)`` and pipelined
``gamma = (r, u)`` — the SAME quantity — so the criterion costs no
extra reduction (it previously burned one on ``(r, r)``) and cannot
drift between recurrences or between ``precond=`` choices.

Differentiability: the dynamic ``while_loop`` body is NOT reverse-mode
differentiable, and unrolling the iteration for autodiff would store
every iterate. Gradients of solutions therefore go through the implicit
function theorem instead — ``x̄ -> λ`` with ``Aᵀ λ = x̄`` — which for the
MGK's SYMMETRIC generalized Laplacian is just a second ``pcg_solve``
with the *identical* matvec closure (:func:`adjoint_solve`). The
``jax.custom_vjp`` that packages this lives in ``core/adjoint.py``
(DESIGN.md §7); this module stays a plain primal solver.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["PCGResult", "pcg_solve", "pcg_solve_segmented",
           "adjoint_solve"]


class PCGResult(NamedTuple):
    x: jnp.ndarray           # [B, N] solution
    iterations: jnp.ndarray  # [B] int32 iterations to convergence
    residual: jnp.ndarray    # [B] final (r, M^{-1} r) — the criterion
    converged: jnp.ndarray   # [B] bool
    # scalar int32: total pair-matvec evaluations the solve performed
    # (lockstep: B per iteration run; segmented: live pairs only). The
    # Gram driver feeds this — with the per-pair ``iterations`` — back
    # into bucket/cost planning (distributed/scheduler.py).
    matvec_pairs: jnp.ndarray | None = None


def _guard(x):
    """Divide-safe denominator (0 -> 1; the numerator is 0 there too)."""
    return jnp.where(x == 0, jnp.asarray(1.0, x.dtype), x)


def _jacobi_apply(diag, r):
    """The default preconditioner application (paper Alg. 1 line 2)."""
    return r / diag


def _wrap_apply(precond_apply):
    """Adapt the public ``precond_apply`` hook (r -> M^{-1} r, or None
    for Jacobi) to the machines' internal ``apply(diag, r)`` signature —
    the ONE adapter shared by the lockstep and segmented solvers."""
    if precond_apply is None:
        return _jacobi_apply
    return lambda diag, r: precond_apply(r)


# -- the two recurrence machines ---------------------------------------------
#
# state: dict of per-pair arrays (EVERY leaf has the leading [B] axis, so
# a gather/scatter remap of the batch is a tree_map) holding the iterates
# plus the per-pair constants (diag preconditioner, convergence
# threshold). body(matvec, apply, state) advances one masked iteration;
# converged pairs are frozen, so running extra masked iterations — or
# running a pair in a different batch composition — never changes its
# trajectory (the segmented-solver contract). ``apply(diag, r)`` is the
# M^{-1} application; convergence is declared on (r, M^{-1} r), which
# both machines already compute (classic: rho; pipelined: gamma), so
# the criterion is the IDENTICAL quantity in every variant under every
# preconditioner — the tolerance-semantics contract of DESIGN.md §9.

def _precond_thresh(rho0, tol):
    eps = jnp.asarray(1e-30, rho0.dtype)
    return (tol * tol) * jnp.maximum(rho0, eps)


def _classic_init(matvec, apply_mz, b, diag_precond, tol):
    del matvec  # classic needs no setup matvec
    r0 = b
    z0 = apply_mz(diag_precond, r0)
    rho0 = jnp.sum(r0 * z0, axis=-1)       # (b, M^{-1} b)
    thresh = _precond_thresh(rho0, tol)
    return dict(
        x=jnp.zeros_like(b), r=r0, p=z0,
        rho=rho0,
        conv=rho0 <= thresh, res=rho0,
        iters=jnp.zeros(b.shape[0], jnp.int32),
        diag=diag_precond, thresh=thresh)


def _classic_body(matvec, apply_mz, st):
    x, r, p, rho = st["x"], st["r"], st["p"], st["rho"]
    conv, res, thresh = st["conv"], st["res"], st["thresh"]
    active = ~conv
    a = matvec(p)                                       # [B, N]
    pa = jnp.sum(p * a, axis=-1)
    alpha = jnp.where(active, rho / _guard(pa), 0.0)
    x = x + alpha[:, None] * p
    r = r - alpha[:, None] * a
    z = apply_mz(st["diag"], r)
    rho_new = jnp.sum(r * z, axis=-1)
    beta = jnp.where(active, rho_new / _guard(rho), 0.0)
    p = jnp.where(active[:, None], z + beta[:, None] * p, p)
    res_new = jnp.where(active, rho_new, res)
    conv = jnp.logical_or(conv, res_new <= thresh)
    return dict(
        x=x, r=r, p=p, rho=jnp.where(active, rho_new, rho),
        conv=conv, res=res_new,
        iters=st["iters"] + active.astype(jnp.int32),
        diag=st["diag"], thresh=thresh)


def _pipelined_init(matvec, apply_mz, b, diag_precond, tol):
    """Chronopoulos–Gear setup: ONE matvec (w0 = A u0)."""
    r0 = b
    u0 = apply_mz(diag_precond, r0)
    w0 = matvec(u0)
    gamma0 = jnp.sum(r0 * u0, axis=-1)     # (b, M^{-1} b)
    delta0 = jnp.sum(w0 * u0, axis=-1)
    thresh = _precond_thresh(gamma0, tol)
    conv0 = gamma0 <= thresh
    zeros = jnp.zeros_like(b)
    return dict(
        x=jnp.zeros_like(b), r=r0, u=u0, w=w0, p=zeros, s=zeros,
        gamma=gamma0,
        alpha=jnp.where(conv0, 0.0, gamma0 / _guard(delta0)),
        beta=jnp.zeros_like(gamma0),
        conv=conv0, res=gamma0,
        iters=jnp.zeros(b.shape[0], jnp.int32),
        diag=diag_precond, thresh=thresh)


def _pipelined_body(matvec, apply_mz, st):
    """Single-reduction (Chronopoulos–Gear) pipelined PCG iteration.

    Per iteration — ONE matvec, ONE fused reduction round:

        p <- u + beta p;   s <- w + beta s        # s = A p by recurrence
        x <- x + alpha p;  r <- r - alpha s
        u = M^{-1} r;      w = A u                # the iteration's matvec
        gamma' = (r, u);  delta = (w, u)          # fused round
        beta'  = gamma' / gamma
        alpha' = gamma' / (delta - beta' * gamma' / alpha)

    alpha is derived from the SAME reduction round as gamma (the classic
    recurrence would need (p, A p), a second, dependent round). The
    convergence check reads gamma' = (r, M^{-1} r) — the classic body's
    rho, post-update — so iteration counts match classic to the
    floating-point drift of the s-recurrence (±1 in practice), and the
    criterion needs no extra (r, r) reduction.
    """
    x, r, u, w = st["x"], st["r"], st["u"], st["w"]
    p, s = st["p"], st["s"]
    gamma, alpha, beta = st["gamma"], st["alpha"], st["beta"]
    conv, res, thresh = st["conv"], st["res"], st["thresh"]
    active = ~conv
    am = active[:, None]
    # -- vector updates from the PREVIOUS round's scalars -----------
    p = jnp.where(am, u + beta[:, None] * p, p)
    s = jnp.where(am, w + beta[:, None] * s, s)   # s = A p, recurred
    x = x + alpha[:, None] * p
    r = r - alpha[:, None] * s
    u = jnp.where(am, apply_mz(st["diag"], r), u)
    w = jnp.where(am, matvec(u), w)               # single matvec
    # -- the single fused reduction round ---------------------------
    gamma_new = jnp.sum(r * u, axis=-1)
    delta = jnp.sum(w * u, axis=-1)
    res_new = jnp.where(active, gamma_new, res)
    conv = jnp.logical_or(conv, res_new <= thresh)
    still = ~conv
    beta = jnp.where(still, gamma_new / _guard(gamma), 0.0)
    alpha = jnp.where(
        still,
        gamma_new / _guard(delta - beta * gamma_new / _guard(alpha)),
        0.0)
    return dict(
        x=x, r=r, u=u, w=w, p=p, s=s,
        gamma=jnp.where(still, gamma_new, gamma), alpha=alpha, beta=beta,
        conv=conv, res=res_new,
        iters=st["iters"] + active.astype(jnp.int32),
        diag=st["diag"], thresh=thresh)


_MACHINES = {"classic": (_classic_init, _classic_body),
             "pipelined": (_pipelined_init, _pipelined_body)}
_SETUP_MATVECS = {"classic": 0, "pipelined": 1}


def _machine(variant: str):
    try:
        return _MACHINES[variant]
    except KeyError:
        raise ValueError(f"unknown PCG variant {variant!r}") from None


def _result(st, matvec_pairs=None) -> PCGResult:
    return PCGResult(x=st["x"], iterations=st["iters"],
                     residual=st["res"], converged=st["conv"],
                     matvec_pairs=matvec_pairs)


def pcg_solve(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    b: jnp.ndarray,
    diag_precond: jnp.ndarray,
    *,
    tol: float = 1e-10,
    max_iter: int = 256,
    fixed_iters: int | None = None,
    variant: str = "classic",
    precond_apply: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
) -> PCGResult:
    """Solve ``A x = b`` for a batch of SPD systems (masked lockstep).

    Args:
      matvec: function mapping [B, N] -> [B, N], applying each system's
        operator to its vector (the on-the-fly XMV plus diagonal terms).
      b: [B, N] right-hand sides.
      diag_precond: [B, N] the diagonal preconditioner M (paper Alg. 1
        line 2); entries must be > 0. Padded entries should be 1.
      tol: relative tolerance; system b is converged when
        (r, M^{-1} r) <= tol^2 * (b, M^{-1} b) — the preconditioned
        residual criterion, identical across variants and solvers for
        any preconditioner (DESIGN.md §9).
      max_iter: iteration cap (a safety net; the paper's systems are
        strongly diagonally dominant and converge in tens of iterations).
      fixed_iters: if set, run EXACTLY this many iterations as a
        known-trip-count scan instead of a dynamic while loop. Production
        batches use this (uniform step count across a bucket — the paper's
        load-balancing premise) and it makes the CG body visible to the
        static roofline profile (analysis/hlo_cost.py multiplies scan
        bodies by their trip count; a dynamic while reports trip=1).
      variant: "classic" (two dependent reduction rounds per iteration) or
        "pipelined" (Ghysels–Vanroose: one fused reduction round that
        overlaps the matvec — see module docstring). Identical iterates in
        exact arithmetic.
      precond_apply: optional ``z = M^{-1} r`` application ([B, N] ->
        [B, N]) replacing the Jacobi ``r / diag_precond`` — the
        Kronecker-factored approximate inverse of ``core/precond.py``
        plugs in here. Must be SPD; the same closure serves the adjoint
        solve (core/adjoint.py reuses it verbatim).

    The result's ``matvec_pairs`` records B x (iterations run + setup
    matvecs) — the lockstep cost that :func:`pcg_solve_segmented` beats
    by retiring converged pairs at segment boundaries.
    """
    init, body = _machine(variant)
    apply_mz = _wrap_apply(precond_apply)
    st0 = init(matvec, apply_mz, b, diag_precond, tol)
    step = functools.partial(body, matvec, apply_mz)
    if fixed_iters is not None:
        def scan_body(s, _):
            return step(s), None
        st, _ = jax.lax.scan(scan_body, st0, None, length=fixed_iters)
        it = jnp.int32(fixed_iters)
    else:
        def cond(carry):
            s, it = carry
            return jnp.logical_and(it < max_iter, ~jnp.all(s["conv"]))

        def wbody(carry):
            s, it = carry
            return step(s), it + 1

        st, it = jax.lax.while_loop(cond, wbody, (st0, jnp.int32(0)))
    B = b.shape[0]
    pairs = B * (it + _SETUP_MATVECS[variant])
    return _result(st, matvec_pairs=pairs)


def pcg_solve_segmented(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    b: jnp.ndarray,
    diag_precond: jnp.ndarray,
    *,
    tol: float = 1e-10,
    max_iter: int = 256,
    segment_size: int = 32,
    variant: str = "classic",
    select: Callable[[np.ndarray],
                     Callable[[jnp.ndarray], jnp.ndarray]] | None = None,
    pad_multiple: int = 1,
    precond_apply: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
) -> PCGResult:
    """Convergence-segmented PCG with pair retirement (DESIGN.md §8).

    Runs the lockstep body in segments of at most ``segment_size``
    masked iterations (each one compiled bounded loop, early-exiting
    when every live pair has converged). Between segments
    the live-pair index set is compacted on the host: pairs that
    converged during the segment RETIRE — their state is scattered back
    into the full-batch result and they drop out of the matvec batch
    entirely via a gather remap — instead of riding along masked to
    ``max_iter``. Because every recurrence and reduction of the body is
    per-pair, the compacted trajectory is iterate-for-iterate identical
    to masked lockstep; only the amount of matvec work changes
    (``matvec_pairs`` in the result counts it).

    Args (beyond :func:`pcg_solve`):
      segment_size: iterations per segment. Within a segment a converged
        pair still rides along masked (frozen); retirement happens at
        segment boundaries.
      select: ``select(indices) -> matvec`` or ``-> (matvec,
        precond_apply)`` building the operator (and, under a
        non-Jacobi preconditioner, the matching ``M^{-1}`` application)
        for a compacted sub-batch, where ``indices`` is a host int
        array of live pair indices into the original batch (the
        Gram-tile / row-panel packs — and the Kronecker preconditioner
        factors — gather along their pair axis,
        ``core/mgk.py:mgk_pairs_sparse_segmented``). Without it no
        compaction happens — segments only add early-exit checks — and
        ``matvec_pairs`` counts the full batch per iteration.
      pad_multiple: round the live-pair count up to this multiple by
        repeating the first live index (bounds jit-shape diversity; the
        duplicate lanes iterate identically and only the real lanes are
        scattered back). 1 = exact compaction.
      precond_apply: as in :func:`pcg_solve` (the full-batch
        application; compacted sub-batches take theirs from ``select``).

    This is a HOST-DRIVEN loop (it cannot run under an enclosing jit);
    each segment itself runs as one compiled bounded loop.
    """
    init, body = _machine(variant)
    if segment_size < 1:
        raise ValueError(f"segment_size must be >= 1, got {segment_size}")
    B = b.shape[0]
    apply_mz = _wrap_apply(precond_apply)
    full = init(matvec, apply_mz, b, diag_precond, tol)
    evals = B * _SETUP_MATVECS[variant]
    live = np.arange(B)           # real live indices (no pad lanes)
    lanes = live                  # live + pad lanes, the gathered batch
    st = full                     # state of the current `lanes` batch
    mv = matvec

    def run_segment(step_body, state, k):
        # bounded loop: at most k masked iterations, early exit the
        # moment every LIVE lane converges (mid-segment iterations on a
        # fully-converged live set would be pure waste)
        def cond(carry):
            s, it = carry
            return jnp.logical_and(it < k, ~jnp.all(s["conv"]))

        def wbody(carry):
            s, it = carry
            return step_body(s), it + 1

        out, it = jax.lax.while_loop(cond, wbody, (state, jnp.int32(0)))
        return out, int(it)

    done = 0
    while done < max_iter and live.size:
        if bool(np.asarray(st["conv"]).all()):
            break
        k = min(segment_size, max_iter - done)
        st, ran = run_segment(functools.partial(body, mv, apply_mz),
                              st, k)
        evals += int(lanes.size) * ran
        done += ran
        if ran == 0:
            break
        # retire: scatter the REAL lanes back, re-gather the survivors
        n_real = live.size
        if lanes.size != B or not np.array_equal(lanes, np.arange(B)):
            idx = jnp.asarray(live)
            full = {f: v.at[idx].set(st[f][:n_real])
                    for f, v in full.items()}
        else:
            full = st
        conv_live = np.asarray(st["conv"])[:n_real]
        new_live = live[~conv_live]
        if new_live.size == 0:
            break
        if select is None or new_live.size == live.size:
            continue      # nothing retired (or no compaction possible)
        live = new_live
        lanes = live
        if pad_multiple > 1 and lanes.size % pad_multiple:
            n_pad = -lanes.size % pad_multiple
            lanes = np.concatenate([lanes, np.repeat(lanes[:1], n_pad)])
        gidx = jnp.asarray(lanes)
        st = {f: jnp.take(v, gidx, axis=0) for f, v in full.items()}
        sel = select(lanes)
        if isinstance(sel, tuple):
            mv, sub_apply = sel
            apply_mz = _wrap_apply(sub_apply)
        else:
            mv = sel
            if precond_apply is not None:
                # a full-batch M^{-1} closure cannot serve a compacted
                # sub-batch; fail loudly instead of on a reshape deep
                # inside the next segment
                raise ValueError(
                    "select must return (matvec, precond_apply) when a"
                    " non-Jacobi precond_apply is in use")
    return _result(full, matvec_pairs=jnp.int32(evals))


def adjoint_solve(
    matvec: Callable[[jnp.ndarray], jnp.ndarray],
    cotangent: jnp.ndarray,
    diag_precond: jnp.ndarray,
    **kw,
) -> PCGResult:
    """Solve the adjoint system ``Aᵀ λ = x̄`` of a forward ``A x = b``.

    The MGK's generalized Laplacian is symmetric (paper Eq. 15), so
    ``Aᵀ = A`` and the adjoint solve IS a forward solve with the same
    matvec closure — same Pallas kernels, same packs, same
    preconditioner, same cost. This alias exists to make that reuse an
    explicit, testable contract (core/adjoint.py builds its backward
    pass on it; DESIGN.md §7) rather than a coincidence at call sites.

    Accepts every :func:`pcg_solve` keyword (tol/max_iter/fixed_iters/
    variant).
    """
    return pcg_solve(matvec, cotangent, diag_precond, **kw)
