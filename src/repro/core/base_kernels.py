"""Base kernels for vertex- and edge-label comparison.

Every base kernel is a positive-definite function kappa(x, y) on the label
set with range in (0, 1] (vertex) or [0, 1] (edge) — the paper's condition
for the generalized Laplacian to stay SPD.

Two evaluation paths (DESIGN.md §2):

* ``__call__(x, y)`` — elementwise, used by the paper-faithful on-the-fly
  XMV (VPU path on TPU).
* ``features(x)`` — an (exact or truncated) symmetric low-rank feature map
  ``phi`` with ``kappa(x, y) = sum_r phi_r(x) * phi_r(y)``, enabling the
  beyond-paper MXU "sandwich" XMV ``y = Σ_r (A⊙φ_r(E)) P (A'⊙φ_r(E'))ᵀ``.
  Returns ``None`` if the kernel admits no useful expansion.

Differentiability (DESIGN.md §7): hyperparameter gradients of the MGK
flow through an adjoint PCG solve (core/adjoint.py), which needs every
base kernel to expose its parameters explicitly:

* ``param_names()`` / ``theta()`` — the differentiable hyperparameters
  and their current values. ``theta()`` is the canonical pytree leaf
  group the gradient entry points take derivatives against.
* ``apply(x, y, theta)`` — evaluate kappa with parameter OVERRIDES taken
  from ``theta`` (a dict; values may be JAX tracers). This is what lets
  the hot-path kernels — whose parameter fields are static Python floats
  baked into the jit cache key — consume traced parameter values: the
  overrides ride along as a tiny f32 vector input (``pack_theta``).
* ``dtheta(x, y, theta)`` — ANALYTIC elementwise derivatives
  ``∂kappa/∂θ`` per parameter. The adjoint contraction
  ``λᵀ (∂A/∂θ) x`` reuses the forward XMV machinery with kappa replaced
  by ``∂kappa/∂θ`` (:class:`ParamDerivative`), so ∂A inherits A's
  sparsity structure and is never materialized.
* ``features_theta(x, theta)`` / ``dfeatures(x, theta)`` — the feature
  expansion and its parameter derivatives, for the MXU paths.

``apply``/``dtheta``/``features_theta`` follow the input dtype (unlike
``__call__``, which keeps its historical float32 cast) so the gradcheck
suite can run the whole pipeline in float64.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

__all__ = [
    "BaseKernel",
    "Constant",
    "KroneckerDelta",
    "SquareExponential",
    "CompactPolynomial",
    "ParamDerivative",
    "pack_theta",
    "unpack_theta",
]


class BaseKernel:
    """Interface for base kernels over scalar labels."""

    def __call__(self, x, y):  # pragma: no cover - interface
        raise NotImplementedError

    def feature_rank(self) -> int | None:
        """Rank of the feature expansion, or None if not available."""
        return None

    def features(self, x):
        """phi(x) with trailing rank axis R, or None."""
        return None

    # -- differentiable-hyperparameter surface (DESIGN.md §7) -----------
    def param_names(self) -> tuple[str, ...]:
        """Names of the differentiable hyperparameters, in a fixed order
        (the order of :func:`pack_theta` vectors)."""
        return ()

    def theta(self) -> dict[str, float]:
        """Current hyperparameter values as a dict pytree."""
        return {n: getattr(self, n) for n in self.param_names()}

    def _p(self, theta, name):
        """Parameter value: ``theta`` override if present, else the
        (static) dataclass field."""
        if theta is not None and name in theta:
            return theta[name]
        return getattr(self, name)

    def apply(self, x, y, theta=None):
        """kappa(x, y) with parameters overridden from ``theta`` (values
        may be tracers). Default: no parameters -> plain ``__call__``."""
        if not self.param_names():
            return self(x, y)
        raise NotImplementedError  # pragma: no cover - interface

    def dtheta(self, x, y, theta=None) -> dict:
        """Analytic elementwise ``∂kappa/∂θ`` per parameter name."""
        if not self.param_names():
            return {}
        raise NotImplementedError  # pragma: no cover - interface

    def features_theta(self, x, theta=None):
        """``features(x)`` with parameter overrides (None if no
        expansion)."""
        if theta is None or not self.param_names():
            return self.features(x)
        raise NotImplementedError  # pragma: no cover - interface

    def dfeatures(self, x, theta=None) -> dict:
        """Analytic ``∂phi/∂θ`` per parameter name, each with the same
        trailing-R shape as ``features(x)``. Only needed when the kernel
        has a feature expansion."""
        if not self.param_names():
            return {}
        raise NotImplementedError  # pragma: no cover - interface


def pack_theta(kernel: BaseKernel, theta=None):
    """Flatten a theta dict to the [P] f32 vector the Pallas kernels take
    as a regular array input (param_names order). None if no params."""
    names = kernel.param_names()
    if not names:
        return None
    vals = [jnp.asarray(kernel._p(theta, n), jnp.float32).reshape(())
            for n in names]
    return jnp.stack(vals)


def unpack_theta(kernel: BaseKernel, vec) -> dict | None:
    """Inverse of :func:`pack_theta`: [P] vector (or a kernel-side ref
    read) back to the {name: scalar} dict ``apply`` expects."""
    if vec is None:
        return None
    names = kernel.param_names()
    return {n: vec[i] for i, n in enumerate(names)}


@dataclasses.dataclass(frozen=True)
class Constant(BaseKernel):
    """kappa(x, y) = c. The unlabeled-graph degenerate case with c = 1."""

    value: float = 1.0

    def __call__(self, x, y):
        return jnp.full(jnp.broadcast_shapes(jnp.shape(x), jnp.shape(y)),
                        self.value, dtype=jnp.result_type(x, y, jnp.float32))

    def feature_rank(self) -> int:
        return 1

    def features(self, x):
        x = jnp.asarray(x)
        return jnp.full(x.shape + (1,), math.sqrt(self.value),
                        dtype=jnp.result_type(x, jnp.float32))

    def param_names(self) -> tuple[str, ...]:
        return ("value",)

    def apply(self, x, y, theta=None):
        c = self._p(theta, "value")
        shape = jnp.broadcast_shapes(jnp.shape(x), jnp.shape(y))
        return jnp.broadcast_to(jnp.asarray(c, jnp.result_type(x, y)),
                                shape)

    def dtheta(self, x, y, theta=None) -> dict:
        shape = jnp.broadcast_shapes(jnp.shape(x), jnp.shape(y))
        return {"value": jnp.ones(shape, jnp.result_type(x, y))}

    def features_theta(self, x, theta=None):
        x = jnp.asarray(x)
        c = self._p(theta, "value")
        root = jnp.sqrt(jnp.asarray(c, jnp.result_type(x, jnp.float32)))
        return jnp.broadcast_to(root, x.shape + (1,))

    def dfeatures(self, x, theta=None) -> dict:
        phi = self.features_theta(x, theta)
        # d sqrt(c) / dc = 1 / (2 sqrt(c))
        return {"value": 0.5 / phi}


@dataclasses.dataclass(frozen=True)
class KroneckerDelta(BaseKernel):
    """kappa(x, y) = 1 if x == y else h,  0 <= h < 1.

    Labels are integer codes in ``[0, n_labels)``. Exact feature expansion of
    rank ``n_labels + 1``:
        kappa = h * 1*1 + (1-h) * sum_c onehot_c(x) onehot_c(y).
    """

    h: float = 0.5
    n_labels: int = 8

    def __call__(self, x, y):
        eq = jnp.asarray(x) == jnp.asarray(y)
        return jnp.where(eq, 1.0, self.h).astype(jnp.float32)

    def feature_rank(self) -> int:
        return self.n_labels + 1

    def features(self, x):
        x = jnp.asarray(x)
        codes = jnp.round(x).astype(jnp.int32)
        onehot = (codes[..., None] == jnp.arange(self.n_labels)).astype(
            jnp.float32)
        const = jnp.full(x.shape + (1,), math.sqrt(self.h), jnp.float32)
        return jnp.concatenate([const, math.sqrt(1.0 - self.h) * onehot],
                               axis=-1)

    def param_names(self) -> tuple[str, ...]:
        return ("h",)

    def apply(self, x, y, theta=None):
        h = self._p(theta, "h")
        eq = jnp.asarray(x) == jnp.asarray(y)
        dt = jnp.result_type(x, y, jnp.float32)
        return jnp.where(eq, jnp.asarray(1.0, dt), jnp.asarray(h, dt))

    def dtheta(self, x, y, theta=None) -> dict:
        eq = jnp.asarray(x) == jnp.asarray(y)
        dt = jnp.result_type(x, y, jnp.float32)
        return {"h": jnp.where(eq, jnp.asarray(0.0, dt),
                               jnp.asarray(1.0, dt))}

    def _onehot(self, x):
        codes = jnp.round(jnp.asarray(x)).astype(jnp.int32)
        dt = jnp.result_type(x, jnp.float32)
        return (codes[..., None] == jnp.arange(self.n_labels)).astype(dt)

    def features_theta(self, x, theta=None):
        h = jnp.asarray(self._p(theta, "h"),
                        jnp.result_type(x, jnp.float32))
        onehot = self._onehot(x)
        const = jnp.broadcast_to(jnp.sqrt(h),
                                 jnp.shape(x) + (1,)).astype(onehot.dtype)
        return jnp.concatenate([const, jnp.sqrt(1.0 - h) * onehot],
                               axis=-1)

    def dfeatures(self, x, theta=None) -> dict:
        h = jnp.asarray(self._p(theta, "h"),
                        jnp.result_type(x, jnp.float32))
        onehot = self._onehot(x)
        const = jnp.broadcast_to(0.5 / jnp.sqrt(h),
                                 jnp.shape(x) + (1,)).astype(onehot.dtype)
        return {"h": jnp.concatenate(
            [const, -0.5 / jnp.sqrt(1.0 - h) * onehot], axis=-1)}


@dataclasses.dataclass(frozen=True)
class SquareExponential(BaseKernel):
    """kappa(x, y) = exp(-alpha (x - y)^2)   (paper Appendix B, example 1).

    Feature expansion (exact in the limit): with
        exp(-a(x-y)^2) = exp(-a x^2) exp(-a y^2) exp(2 a x y)
    and the Taylor series exp(2axy) = sum_k (2a)^k x^k y^k / k!, the rank-R
    truncation has features
        phi_k(x) = exp(-a x^2) sqrt((2a)^k / k!) x^k,  k = 0..R-1.
    For labels normalized to [0, 1] and alpha ~ O(1), R = 12 reaches ~1e-7
    max truncation error (validated in tests/test_base_kernels.py).
    """

    alpha: float = 1.0
    rank: int = 12
    domain: float = 1.0   # |labels| <= domain keeps the expansion accurate

    def __call__(self, x, y):
        d = jnp.asarray(x) - jnp.asarray(y)
        return jnp.exp(-self.alpha * d * d).astype(jnp.float32)

    def feature_rank(self) -> int:
        return self.rank

    def features(self, x):
        x = jnp.asarray(x, jnp.float32)
        ks = jnp.arange(self.rank, dtype=jnp.float32)
        # log coefficients: 0.5 * (k log(2a) - log k!)
        log_coeff = 0.5 * (ks * math.log(2.0 * self.alpha)
                           - jnp.cumsum(jnp.log(jnp.maximum(ks, 1.0))))
        coeff = jnp.exp(log_coeff)
        powers = x[..., None] ** ks
        env = jnp.exp(-self.alpha * x * x)[..., None]
        return env * coeff * powers

    def param_names(self) -> tuple[str, ...]:
        return ("alpha",)

    def apply(self, x, y, theta=None):
        a = self._p(theta, "alpha")
        d = jnp.asarray(x) - jnp.asarray(y)
        return jnp.exp(-a * d * d)

    def dtheta(self, x, y, theta=None) -> dict:
        a = self._p(theta, "alpha")
        d2 = (jnp.asarray(x) - jnp.asarray(y)) ** 2
        return {"alpha": -d2 * jnp.exp(-a * d2)}

    def features_theta(self, x, theta=None):
        x = jnp.asarray(x)
        dt = jnp.result_type(x, jnp.float32)
        x = x.astype(dt)
        a = jnp.asarray(self._p(theta, "alpha"), dt)
        ks = jnp.arange(self.rank, dtype=dt)
        log_coeff = 0.5 * (ks * jnp.log(2.0 * a)
                           - jnp.cumsum(jnp.log(jnp.maximum(ks, 1.0))))
        coeff = jnp.exp(log_coeff)
        powers = x[..., None] ** ks
        env = jnp.exp(-a * x * x)[..., None]
        return env * coeff * powers

    def dfeatures(self, x, theta=None) -> dict:
        # phi_k = exp(-a x^2) sqrt((2a)^k / k!) x^k
        #   => d phi_k / da = phi_k * (k / (2a) - x^2)
        x = jnp.asarray(x)
        dt = jnp.result_type(x, jnp.float32)
        x = x.astype(dt)
        a = jnp.asarray(self._p(theta, "alpha"), dt)
        phi = self.features_theta(x, theta)
        ks = jnp.arange(self.rank, dtype=dt)
        return {"alpha": phi * (ks / (2.0 * a) - (x * x)[..., None])}


@dataclasses.dataclass(frozen=True)
class CompactPolynomial(BaseKernel):
    """Degree-n compact polynomial RBF kappa(x,y) = clip(sum_i a_i (x-y)^i).

    Paper Appendix B example 2 (Wendland-type compact kernels). Default is
    the C2 Wendland kernel on [0, 1]: (1-d)^4 (4d + 1), clipped at d = 1.
    No useful symmetric low-rank expansion — elementwise path only — which
    exercises the kernels' VPU fallback.
    """

    support: float = 1.0

    def __call__(self, x, y):
        d = jnp.abs(jnp.asarray(x) - jnp.asarray(y)) / self.support
        d = jnp.minimum(d, 1.0)
        return ((1.0 - d) ** 4 * (4.0 * d + 1.0)).astype(jnp.float32)

    def param_names(self) -> tuple[str, ...]:
        return ("support",)

    def apply(self, x, y, theta=None):
        s = self._p(theta, "support")
        d = jnp.abs(jnp.asarray(x) - jnp.asarray(y)) / s
        d = jnp.minimum(d, 1.0)
        return (1.0 - d) ** 4 * (4.0 * d + 1.0)

    def dtheta(self, x, y, theta=None) -> dict:
        # kappa(d) = (1-d)^4 (4d+1),  d = |x-y|/s  (clipped at 1):
        #   d kappa / dd = -20 d (1-d)^3,  dd/ds = -d/s
        #   => d kappa / ds = 20 d^2 (1-d)^3 / s  (0 beyond the support;
        #      continuous at d = 1 where the factor (1-d)^3 vanishes)
        s = self._p(theta, "support")
        raw = jnp.abs(jnp.asarray(x) - jnp.asarray(y)) / s
        d = jnp.minimum(raw, 1.0)
        g = 20.0 * d * d * (1.0 - d) ** 3 / s
        return {"support": jnp.where(raw < 1.0, g, jnp.zeros_like(g))}


@dataclasses.dataclass(frozen=True)
class ParamDerivative(BaseKernel):
    """The elementwise derivative ``∂kappa/∂θ_name`` of a base kernel,
    itself packaged as a (non-PSD) "kernel" so the adjoint contraction
    ``λᵀ (∂A/∂θ) x`` can reuse the forward XMV machinery verbatim — the
    same Pallas kernels, the same packs, the same sparsity (DESIGN.md
    §7). Hashable (the wrapped kernel is a frozen dataclass), so it
    rides the same static-argument slots as the kernel it derives."""

    base: BaseKernel
    name: str

    def __call__(self, x, y):
        return self.base.dtheta(x, y, None)[self.name]

    def param_names(self) -> tuple[str, ...]:
        # same parameter vector as the base kernel, so pack_theta /
        # unpack_theta round-trip transparently through the XMV wrappers
        return self.base.param_names()

    def theta(self) -> dict[str, float]:
        return self.base.theta()

    def apply(self, x, y, theta=None):
        return self.base.dtheta(x, y, theta)[self.name]
