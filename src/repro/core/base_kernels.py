"""Base kernels for vertex- and edge-label comparison.

Every base kernel is a positive-definite function kappa(x, y) on the label
set with range in (0, 1] (vertex) or [0, 1] (edge) — the paper's condition
for the generalized Laplacian to stay SPD.

Two evaluation paths (DESIGN.md §2):

* ``__call__(x, y)`` — elementwise, used by the paper-faithful on-the-fly
  XMV (VPU path on TPU).
* ``features(x)`` — an (exact or truncated) symmetric low-rank feature map
  ``phi`` with ``kappa(x, y) = sum_r phi_r(x) * phi_r(y)``, enabling the
  beyond-paper MXU "sandwich" XMV ``y = Σ_r (A⊙φ_r(E)) P (A'⊙φ_r(E'))ᵀ``.
  Returns ``None`` if the kernel admits no useful expansion.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

__all__ = [
    "BaseKernel",
    "Constant",
    "KroneckerDelta",
    "SquareExponential",
    "CompactPolynomial",
]


class BaseKernel:
    """Interface for base kernels over scalar labels."""

    def __call__(self, x, y):  # pragma: no cover - interface
        raise NotImplementedError

    def feature_rank(self) -> int | None:
        """Rank of the feature expansion, or None if not available."""
        return None

    def features(self, x):
        """phi(x) with trailing rank axis R, or None."""
        return None


@dataclasses.dataclass(frozen=True)
class Constant(BaseKernel):
    """kappa(x, y) = c. The unlabeled-graph degenerate case with c = 1."""

    value: float = 1.0

    def __call__(self, x, y):
        return jnp.full(jnp.broadcast_shapes(jnp.shape(x), jnp.shape(y)),
                        self.value, dtype=jnp.result_type(x, y, jnp.float32))

    def feature_rank(self) -> int:
        return 1

    def features(self, x):
        x = jnp.asarray(x)
        return jnp.full(x.shape + (1,), math.sqrt(self.value),
                        dtype=jnp.result_type(x, jnp.float32))


@dataclasses.dataclass(frozen=True)
class KroneckerDelta(BaseKernel):
    """kappa(x, y) = 1 if x == y else h,  0 <= h < 1.

    Labels are integer codes in ``[0, n_labels)``. Exact feature expansion of
    rank ``n_labels + 1``:
        kappa = h * 1*1 + (1-h) * sum_c onehot_c(x) onehot_c(y).
    """

    h: float = 0.5
    n_labels: int = 8

    def __call__(self, x, y):
        eq = jnp.asarray(x) == jnp.asarray(y)
        return jnp.where(eq, 1.0, self.h).astype(jnp.float32)

    def feature_rank(self) -> int:
        return self.n_labels + 1

    def features(self, x):
        x = jnp.asarray(x)
        codes = jnp.round(x).astype(jnp.int32)
        onehot = (codes[..., None] == jnp.arange(self.n_labels)).astype(
            jnp.float32)
        const = jnp.full(x.shape + (1,), math.sqrt(self.h), jnp.float32)
        return jnp.concatenate([const, math.sqrt(1.0 - self.h) * onehot],
                               axis=-1)


@dataclasses.dataclass(frozen=True)
class SquareExponential(BaseKernel):
    """kappa(x, y) = exp(-alpha (x - y)^2)   (paper Appendix B, example 1).

    Feature expansion (exact in the limit): with
        exp(-a(x-y)^2) = exp(-a x^2) exp(-a y^2) exp(2 a x y)
    and the Taylor series exp(2axy) = sum_k (2a)^k x^k y^k / k!, the rank-R
    truncation has features
        phi_k(x) = exp(-a x^2) sqrt((2a)^k / k!) x^k,  k = 0..R-1.
    For labels normalized to [0, 1] and alpha ~ O(1), R = 12 reaches ~1e-7
    max truncation error (validated in tests/test_base_kernels.py).
    """

    alpha: float = 1.0
    rank: int = 12
    domain: float = 1.0   # |labels| <= domain keeps the expansion accurate

    def __call__(self, x, y):
        d = jnp.asarray(x) - jnp.asarray(y)
        return jnp.exp(-self.alpha * d * d).astype(jnp.float32)

    def feature_rank(self) -> int:
        return self.rank

    def features(self, x):
        x = jnp.asarray(x, jnp.float32)
        ks = jnp.arange(self.rank, dtype=jnp.float32)
        # log coefficients: 0.5 * (k log(2a) - log k!)
        log_coeff = 0.5 * (ks * math.log(2.0 * self.alpha)
                           - jnp.cumsum(jnp.log(jnp.maximum(ks, 1.0))))
        coeff = jnp.exp(log_coeff)
        powers = x[..., None] ** ks
        env = jnp.exp(-self.alpha * x * x)[..., None]
        return env * coeff * powers


@dataclasses.dataclass(frozen=True)
class CompactPolynomial(BaseKernel):
    """Degree-n compact polynomial RBF kappa(x,y) = clip(sum_i a_i (x-y)^i).

    Paper Appendix B example 2 (Wendland-type compact kernels). Default is
    the C2 Wendland kernel on [0, 1]: (1-d)^4 (4d + 1), clipped at d = 1.
    No useful symmetric low-rank expansion — elementwise path only — which
    exercises the kernels' VPU fallback.
    """

    support: float = 1.0

    def __call__(self, x, y):
        d = jnp.abs(jnp.asarray(x) - jnp.asarray(y)) / self.support
        d = jnp.minimum(d, 1.0)
        return ((1.0 - d) ** 4 * (4.0 * d + 1.0)).astype(jnp.float32)
