"""The marginalized graph kernel (paper Eq. 15) — the library's core API.

    K(G, G') = p_x^T (D_x V_x^{-1} - A_x .* E_x)^{-1} D_x q_x

computed with the batched PCG of core/pcg.py and one of the XMV backends:

  method = "full"         exact product materialization (naive baseline)
           "elementwise"  paper-faithful streaming XMV (jnp)
           "lowrank"      beyond-paper MXU sandwich (feature expansion)
           "pallas"       Pallas TPU tiling&blocking kernel
           "pallas_sparse" Pallas block-sparse octile kernel; row-panel
                          packs select the VMEM-staged row-panel kernel
                          whose in-kernel slot reduction runs either
                          elementwise (VPU) or as the MXU low-rank
                          contraction (``sparse_mode``)
           "adaptive"     density-based host dispatch (paper Sec. IV-B)

Batched over pairs: both operands are GraphBatch pytrees of equal batch
size; entry b of the output compares batch1[b] with batch2[b]. The
all-pairs Gram matrix driver lives in distributed/gram.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base_kernels import BaseKernel, Constant
from .graph import GraphBatch
from .pcg import GuardSpec, MatvecFault, PCGResult, pcg_solve, \
    pcg_solve_segmented
from .xmv import xmv_elementwise, xmv_full, xmv_lowrank_precomputed, \
    weighted_operands

__all__ = ["MGKResult", "mgk_pairs", "mgk_single", "ProductSystem",
           "build_product_system", "mgk_pairs_sparse",
           "mgk_pairs_sparse_segmented", "mgk_adaptive",
           "adaptive_route", "stop_prob_override"]


class ProductSystem(NamedTuple):
    """Diagonal terms of the product-graph linear system, [B, n*m] each."""
    dx: jnp.ndarray      # d (x) d'
    vx: jnp.ndarray      # kappa_v(v_i, v'_i')
    qx: jnp.ndarray      # q (x) q'
    px: jnp.ndarray      # p (x) p'
    mask: jnp.ndarray    # node_mask (x) node_mask'


class MGKResult(NamedTuple):
    values: jnp.ndarray       # [B] kernel values
    iterations: jnp.ndarray   # [B] CG iterations
    converged: jnp.ndarray    # [B]
    nodal: jnp.ndarray | None  # [B, n, m] node-wise similarity (V_x r_inf)
    # scalar: total pair-matvec evaluations of the solve (PCGResult
    # passthrough) — the segmented-vs-lockstep work metric (DESIGN.md §8)
    matvec_pairs: jnp.ndarray | None = None
    # [B] int32 PCG_* status bitmask (PCGResult passthrough, DESIGN.md
    # §10): 0 clean, MAX_ITER slow-but-sane, any cause flag = guard
    # intervened — the Gram driver's degradation-ladder signal
    status: jnp.ndarray | None = None


def _outer_flat(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Batched Kronecker of vectors: [B, n], [B, m] -> [B, n*m]."""
    return (a[:, :, None] * b[:, None, :]).reshape(a.shape[0], -1)


def stop_prob_override(g: GraphBatch, q) -> GraphBatch:
    """Rebuild a batch's stopping probability (and the degrees derived
    from it, paper's d_i = Σ_j A_ij + q_i) from a scalar ``q`` — possibly
    a tracer, the differentiable-hyperparameter path of core/adjoint.py.
    Padding conventions preserved: stop zero-padded, degrees one-padded."""
    stop = q * g.node_mask
    deg = jnp.where(g.node_mask > 0, g.adjacency.sum(-1) + stop,
                    jnp.ones_like(stop))
    return g._replace(stop_prob=stop, degrees=deg)


def build_product_system(g1: GraphBatch, g2: GraphBatch,
                         vertex_kernel: BaseKernel,
                         theta_v=None, q=None) -> ProductSystem:
    """Diagonal terms of the product system. ``theta_v`` overrides the
    vertex kernel's hyperparameters with (possibly traced) values via
    ``BaseKernel.apply``; scalar ``q`` overrides both graphs' stopping
    probability (DESIGN.md §7)."""
    if q is not None:
        g1 = stop_prob_override(g1, q)
        g2 = stop_prob_override(g2, q)
    mask = _outer_flat(g1.node_mask, g2.node_mask)
    x1 = g1.vertex_labels[:, :, None]
    x2 = g2.vertex_labels[:, None, :]
    vx = (vertex_kernel(x1, x2) if theta_v is None
          else vertex_kernel.apply(x1, x2, theta_v)).reshape(mask.shape)
    # padded entries: vx=1, dx=1 keeps the padded diagonal SPD & decoupled
    vx = jnp.where(mask > 0, vx, 1.0)
    dx = _outer_flat(g1.degrees, g2.degrees)
    dx = jnp.where(mask > 0, dx, 1.0)
    qx = _outer_flat(g1.stop_prob, g2.stop_prob) * mask
    px = _outer_flat(g1.start_prob, g2.start_prob) * mask
    return ProductSystem(dx=dx, vx=vx, qx=qx, px=px, mask=mask)


def _make_matvec(g1: GraphBatch, g2: GraphBatch, sys_: ProductSystem,
                 edge_kernel: BaseKernel, method: str, chunk: int,
                 theta_e=None, raw: bool = False):
    """Returns matvec([B, n*m]) applying (D_x V_x^{-1} - A_x .* E_x).

    ``theta_e`` (dict, values possibly traced) overrides the edge
    kernel's hyperparameters on every backend; ``raw=True`` instead
    returns the pure XMV application ``p -> (A_x .* E_x) p`` (no
    diagonal) — the building block of the adjoint parameter contraction
    ``λᵀ (∂A/∂θ) x``, which runs these same backends with kappa replaced
    by ∂kappa/∂θ (core/adjoint.py, DESIGN.md §7)."""
    B, n = g1.adjacency.shape[0], g1.adjacency.shape[1]
    m = g2.adjacency.shape[1]
    diag = None if raw else sys_.dx / sys_.vx

    if method == "lowrank":
        wo = lambda a, e: weighted_operands(a, e, edge_kernel,   # noqa
                                            theta=theta_e)
        wa = jax.vmap(wo)(g1.adjacency, g1.edge_labels)   # [B, R, n, n]
        wap = jax.vmap(wo)(g2.adjacency, g2.edge_labels)  # [B, R, m, m]

        def matvec(p_vec):
            P = p_vec.reshape(B, n, m)
            y = jax.vmap(xmv_lowrank_precomputed)(wa, wap, P)
            y = y.reshape(B, -1)
            return y if raw else diag * p_vec - y
        return matvec

    if method == "pallas":
        # imported lazily: kernels package depends on core
        from repro.kernels import ops as kops
        from .base_kernels import pack_theta
        tvec = None if theta_e is None else pack_theta(edge_kernel,
                                                       theta_e)
        diag_nm = None if raw else diag.reshape(B, n, m)

        def matvec(p_vec):
            # fused epilogue: the kernel itself emits diag*p - y, so one
            # launch IS the whole operator application (DESIGN.md §3)
            P = p_vec.reshape(B, n, m)
            out = kops.xmv_dense_batched(g1.adjacency, g1.edge_labels,
                                         g2.adjacency, g2.edge_labels, P,
                                         edge_kernel, diag=diag_nm,
                                         theta=tvec)
            return out.reshape(B, -1)
        return matvec

    if method == "full":
        xmv_one = functools.partial(xmv_full, edge_kernel=edge_kernel,
                                    theta=theta_e)
    elif method == "elementwise":
        xmv_one = functools.partial(xmv_elementwise,
                                    edge_kernel=edge_kernel, chunk=chunk,
                                    theta=theta_e)
    else:
        raise ValueError(f"unknown method {method!r}")

    def matvec(p_vec):
        P = p_vec.reshape(B, n, m)
        y = jax.vmap(lambda a, e, ap, ep, pp: xmv_one(a, e, ap, ep, pp))(
            g1.adjacency, g1.edge_labels, g2.adjacency, g2.edge_labels, P)
        y = y.reshape(B, -1)
        return y if raw else diag * p_vec - y
    return matvec


def _resolve_kron_factors(g1: GraphBatch, g2: GraphBatch,
                          gram_tile: tuple[int, int] | None,
                          factors1=None, factors2=None):
    """Cached-or-derived :class:`~repro.core.precond.KronFactors` for a
    pair batch — the ONE place the gram-tile slicing convention is
    encoded for the preconditioner: under ``gram_tile=(Bi, Bj)`` the
    row-major pair-flattened batches carry the unique row graphs at
    strides of Bj and the unique column graphs as the first Bj entries
    (matching ``distributed.gram._axis_structure``)."""
    from .precond import kron_factors
    if gram_tile is not None:
        Bj = gram_tile[1]
        if factors1 is None:
            factors1 = kron_factors(jax.tree.map(lambda x: x[::Bj], g1))
        if factors2 is None:
            factors2 = kron_factors(jax.tree.map(lambda x: x[:Bj], g2))
        return factors1, factors2
    return (factors1 if factors1 is not None else kron_factors(g1),
            factors2 if factors2 is not None else kron_factors(g2))


def _make_precond_apply(precond: str, g1: GraphBatch, g2: GraphBatch,
                        vertex_kernel: BaseKernel,
                        edge_kernel: BaseKernel,
                        shape: tuple[int, int, int],
                        gram_tile: tuple[int, int] | None = None,
                        factors1=None, factors2=None,
                        kron_rank: int = 2, spd_margin=None):
    """The ``M^{-1}`` application for the PCG solve, shared by every
    entry point and the adjoint path (DESIGN.md §9):

    * ``precond="jacobi"`` -> None (``pcg_solve`` falls back to the
      paper's ``r / diag``);
    * ``precond="kron"`` -> the Kronecker-factored approximate-inverse
      apply of ``core/precond.py``. ``factors1``/``factors2`` are
      optional precomputed :class:`~repro.core.precond.KronFactors`
      (the Gram driver's pack-time cache); without them the factors are
      derived in-trace from the batches — O(B n²), amortized over the
      whole solve. Under ``gram_tile=(Bi, Bj)`` the factors are
      PER-AXIS (row graphs / column graphs), sliced from the row-major
      pair-flattened batches exactly like the per-axis packs.

    ``spd_margin`` (possibly traced) overrides the §9.2 SPD-certificate
    margin; negative values are the certificate-failure injection seam
    (core/precond.py:kron_scalars, DESIGN.md §10).
    """
    if precond == "jacobi":
        return None
    if precond != "kron":
        raise ValueError(f"unknown precond {precond!r}")
    from .precond import kron_apply, kron_apply_gram
    B, n, m = shape
    factors1, factors2 = _resolve_kron_factors(g1, g2, gram_tile,
                                               factors1, factors2)
    if gram_tile is not None:
        Bi, Bj = gram_tile
        return kron_apply_gram(factors1, factors2, vertex_kernel,
                               edge_kernel, (Bi, Bj, n, m),
                               rank=kron_rank, spd_margin=spd_margin)
    return kron_apply(factors1, factors2, vertex_kernel, edge_kernel,
                      (B, n, m), rank=kron_rank, spd_margin=spd_margin)


def _make_sparse_matvec(sys_: ProductSystem, packs1, packs2,
                        edge_kernel: BaseKernel, sparse_mode: str,
                        shape: tuple[int, int, int],
                        theta_e=None, raw: bool = False,
                        gram_tile: tuple[int, int] | None = None):
    """Block-sparse analogue of :func:`_make_matvec` over stacked packs
    (RowPanelPack -> row-panel kernel, TilePack -> legacy batched grid).

    With ``gram_tile=(Bi, Bj)`` the packs are PER-AXIS instead of
    per-pair — ``packs1`` holds the Bi row graphs, ``packs2`` the Bj
    column graphs — and the whole B = Bi*Bj cross-product matvec runs
    as ONE ``xmv_gram_tile`` launch (pair b = bi*Bj + bj, row-major;
    DESIGN.md §8). The [B, n*m] vector contract is unchanged, so the
    PCG solvers and the adjoint path dispatch to it unmodified.

    With ``theta_e``, traced edge hyperparameters reach the kernels two
    ways (DESIGN.md §7): the elementwise mode takes a packed theta
    vector straight into the Pallas kernel; the MXU mode re-derives the
    weighted operands ``values_w`` on device from the pack's structural
    fields (``device_weighted_pack``) — unless the pack already carries
    weights and ``theta_e`` is None, in which case the pack-time host
    precompute is trusted as-is."""
    from repro.kernels.ops import RowPanelPack, device_weighted_pack, \
        xmv_block_sparse_batched, xmv_gram_tile, xmv_row_panel_batched
    from .base_kernels import pack_theta

    B, n, m = shape
    diag = None if raw else sys_.dx / sys_.vx
    row_panel = isinstance(packs1, RowPanelPack)
    if gram_tile is not None and not row_panel:
        raise ValueError("gram_tile needs RowPanelPack per-axis packs"
                         " (legacy TilePacks have no Gram-tile kernel)")
    tvec = None
    if row_panel:
        have_w = packs1.values_w is not None and \
            packs2.values_w is not None
        # "auto" follows the PACK-TIME intent exactly like _resolve_mode:
        # packs built without weights run elementwise (exact, theta via
        # the in-kernel vector) even when the edge kernel could expand —
        # a theta override must not silently introduce truncation error
        mxu = sparse_mode == "mxu" or (sparse_mode == "auto" and have_w)
        if mxu and (theta_e is not None or not have_w):
            packs1 = device_weighted_pack(packs1, edge_kernel,
                                          theta=theta_e)
            packs2 = device_weighted_pack(packs2, edge_kernel,
                                          theta=theta_e)
        if not mxu and theta_e is not None:
            tvec = pack_theta(edge_kernel, theta_e)
        mode = "mxu" if mxu else "elementwise"

    if gram_tile is not None:
        Bi, Bj = gram_tile
        if Bi * Bj != B:
            raise ValueError(
                f"gram_tile {gram_tile} inconsistent with batch {B}")
        diag_t = None if raw else diag.reshape(Bi, Bj, n, m)

        def matvec(p_vec):
            P = p_vec.reshape(Bi, Bj, n, m)
            out = xmv_gram_tile(packs1, packs2, P, edge_kernel,
                                diag=diag_t, mode=mode, theta=tvec)
            return out.reshape(B, -1)
        return matvec

    diag_nm = None if raw else diag.reshape(B, n, m)

    def matvec(p_vec):
        # with diag: the fused in-kernel epilogue emits diag*p - y (the
        # full operator application); raw mode (diag None) emits +y, the
        # pure XMV the adjoint contraction needs
        P = p_vec.reshape(B, n, m)
        if row_panel:
            out = xmv_row_panel_batched(packs1, packs2, P, edge_kernel,
                                        diag=diag_nm, mode=mode,
                                        theta=tvec)
        else:
            out = xmv_block_sparse_batched(packs1, packs2, P, edge_kernel,
                                           diag=diag_nm)
        return out.reshape(B, -1)
    return matvec


@functools.partial(
    jax.jit,
    static_argnames=("vertex_kernel", "edge_kernel", "method", "chunk",
                     "max_iter", "return_nodal", "fixed_iters",
                     "pcg_variant", "precond", "kron_rank", "guard",
                     "fault"))
def mgk_pairs(
    g1: GraphBatch,
    g2: GraphBatch,
    vertex_kernel: BaseKernel = Constant(1.0),
    edge_kernel: BaseKernel = Constant(1.0),
    *,
    method: str = "lowrank",
    chunk: int = 8,
    tol: float = 1e-10,
    max_iter: int = 512,
    return_nodal: bool = False,
    fixed_iters: int | None = None,
    pcg_variant: str = "classic",
    precond: str = "jacobi",
    kron_rank: int = 2,
    guard: GuardSpec | bool | None = True,
    fault: MatvecFault | None = None,
    spd_margin=None,
) -> MGKResult:
    """Marginalized graph kernel between aligned pairs of two batches.

    ``precond``: "jacobi" (paper Alg. 1 line 2) or "kron" — the
    Kronecker-factored approximate inverse of ``core/precond.py``
    (rank ``kron_rank`` ∈ {1, 2}), which cuts PCG iteration counts at
    identical solutions (DESIGN.md §9).

    ``guard``/``fault``/``spd_margin``: PCG numerical guards, the
    matvec fault-injection seam, and the (possibly traced) SPD-margin
    override — see core/pcg.py and DESIGN.md §10. All three reach the
    solve as jit ARGUMENTS (guard/fault static, spd_margin traced), so
    arming them retraces instead of fighting cached traces."""
    sys_ = build_product_system(g1, g2, vertex_kernel)
    B, n = g1.adjacency.shape[0], g1.adjacency.shape[1]
    m = g2.adjacency.shape[1]
    matvec = _make_matvec(g1, g2, sys_, edge_kernel, method, chunk)
    rhs = sys_.dx * sys_.qx
    diag = sys_.dx / sys_.vx         # paper Alg. 1 line 2
    papply = _make_precond_apply(precond, g1, g2, vertex_kernel,
                                 edge_kernel, (B, n, m),
                                 kron_rank=kron_rank,
                                 spd_margin=spd_margin)
    sol: PCGResult = pcg_solve(matvec, rhs, diag, tol=tol,
                               max_iter=max_iter, fixed_iters=fixed_iters,
                               variant=pcg_variant,
                               precond_apply=papply, guard=guard,
                               fault=fault)
    values = jnp.sum(sys_.px * sol.x, axis=-1)
    nodal = sol.x.reshape(B, n, m) if return_nodal else None
    return MGKResult(values=values, iterations=sol.iterations,
                     converged=sol.converged, nodal=nodal,
                     matvec_pairs=sol.matvec_pairs, status=sol.status)


def mgk_single(g1: GraphBatch, g2: GraphBatch, **kw) -> MGKResult:
    """Convenience wrapper for batch size 1."""
    return mgk_pairs(g1, g2, **kw)


def tile_density(batch: GraphBatch, tile: int = 8) -> float:
    """Host-side fraction of non-empty octiles (mean over the batch)."""
    import numpy as np
    from .octile import count_nonempty_tiles
    dens = []
    for b in range(batch.adjacency.shape[0]):
        a = np.asarray(batch.adjacency[b])
        nt = a.shape[0] // tile
        dens.append(count_nonempty_tiles(a, tile) / max(nt * nt, 1))
    return float(np.mean(dens))


def adaptive_route(g1: GraphBatch, g2: GraphBatch,
                   edge_kernel: BaseKernel,
                   density_threshold: float = 0.15,
                   tile: int = 8) -> tuple[str, int]:
    """The adaptive dispatch DECISION (host-side), shared by
    :func:`mgk_adaptive` and the differentiable entry points of
    ``core/adjoint.py`` so both walk the same table:

    =============  ==================  =====================================
    octile dens.   feature expansion   route
    =============  ==================  =====================================
    < threshold    usable              "sparse_mxu"  (row-panel, MXU)
    < threshold    none                "sparse_vpu"  (row-panel, VPU)
    >= threshold   usable              "lowrank"     (dense MXU sandwich)
    >= threshold   none                "pallas"      (dense tiling kernel)
    =============  ==================  =====================================

    "usable" = ``feature_rank()`` is not None, the rank is small against
    ``density * n``, and the labels stay inside the expansion's accuracy
    domain (the SE Taylor truncation) — otherwise exact elementwise
    paths. Returns (route, tile) with ``tile`` shrunk to the largest of
    {tile, 16, 8} dividing the bucket's padded size.
    """
    import numpy as np
    rank = edge_kernel.feature_rank()
    n, m = g1.adjacency.shape[1], g2.adjacency.shape[1]
    while tile > 8 and (n % tile or m % tile):
        tile //= 2
    dens = max(tile_density(g1, tile), tile_density(g2, tile))
    # the SE Taylor expansion is only accurate within its label domain —
    # outside it, fall back to exact elementwise paths
    domain = getattr(edge_kernel, "domain", None)
    if domain is not None:
        lmax = max(float(np.abs(np.asarray(g1.edge_labels)).max()),
                   float(np.abs(np.asarray(g2.edge_labels)).max()))
        if lmax > domain:
            rank = None
    rank_usable = rank is not None and rank <= max(16, dens * n)
    if dens < density_threshold:
        return ("sparse_mxu" if rank_usable else "sparse_vpu"), tile
    return ("lowrank" if rank_usable else "pallas"), tile


def mgk_adaptive(g1: GraphBatch, g2: GraphBatch,
                 vertex_kernel: BaseKernel = Constant(1.0),
                 edge_kernel: BaseKernel = Constant(1.0),
                 *, density_threshold: float = 0.15,
                 tile: int = 8,
                 tol: float = 1e-10, max_iter: int = 512,
                 fixed_iters: int | None = None,
                 pcg_variant: str = "classic",
                 precond: str = "jacobi",
                 kron_rank: int = 2,
                 guard: GuardSpec | bool | None = True,
                 fault: MatvecFault | None = None,
                 spd_margin=None) -> MGKResult:
    """The paper's adaptive primitive switch (Sec. IV-B), lifted to the
    bucket level: pick the XMV backend per pair-batch from the octile
    density statistic AND the edge kernel's feature expansion — the
    :func:`adaptive_route` table (DESIGN.md §3.4). ``precond`` rides
    along to whichever backend wins the dispatch."""
    route, tile = adaptive_route(g1, g2, edge_kernel,
                                 density_threshold=density_threshold,
                                 tile=tile)
    kw = dict(tol=tol, max_iter=max_iter, fixed_iters=fixed_iters,
              pcg_variant=pcg_variant, precond=precond,
              kron_rank=kron_rank, guard=guard, fault=fault,
              spd_margin=spd_margin)
    if route.startswith("sparse"):
        from repro.kernels.ops import row_panel_packs_for_batch
        ek_pack = edge_kernel if route == "sparse_mxu" else None
        return mgk_pairs_sparse(
            g1, g2,
            row_panel_packs_for_batch(g1, tile=tile, edge_kernel=ek_pack),
            row_panel_packs_for_batch(g2, tile=tile, edge_kernel=ek_pack),
            vertex_kernel, edge_kernel,
            sparse_mode="mxu" if route == "sparse_mxu" else "elementwise",
            **kw)
    return mgk_pairs(g1, g2, vertex_kernel, edge_kernel, method=route,
                     **kw)


@functools.partial(
    jax.jit,
    static_argnames=("vertex_kernel", "edge_kernel", "max_iter",
                     "return_nodal", "fixed_iters", "pcg_variant",
                     "sparse_mode", "gram_tile", "precond", "kron_rank",
                     "guard", "fault"))
def mgk_pairs_sparse(
    g1: GraphBatch,
    g2: GraphBatch,
    packs1,                      # stacked RowPanelPack or legacy TilePack
    packs2,
    vertex_kernel: BaseKernel = Constant(1.0),
    edge_kernel: BaseKernel = Constant(1.0),
    *,
    sparse_mode: str = "auto",
    tol: float = 1e-10,
    max_iter: int = 512,
    return_nodal: bool = False,
    fixed_iters: int | None = None,
    pcg_variant: str = "classic",
    gram_tile: tuple[int, int] | None = None,
    precond: str = "jacobi",
    kron_rank: int = 2,
    factors1=None,               # optional cached KronFactors (per-pair
    factors2=None,               # stacked, or PER-AXIS under gram_tile)
    guard: GuardSpec | bool | None = True,
    fault: MatvecFault | None = None,
    spd_margin=None,
) -> MGKResult:
    """Block-sparse-octile variant of mgk_pairs (paper Sec. IV).

    The packs are host-preprocessed (``row_panel_packs_for_batch`` /
    ``packs_for_batch`` after reordering) — the quadratic CG work then
    touches only non-empty octiles. GraphBatch still supplies the
    diagonal/probability vectors (cheap, O(n+m)).

    Stacked :class:`~repro.kernels.xmv_block_sparse.RowPanelPack` inputs
    run the row-panel kernel (VMEM tile-row reuse, in-kernel slot
    reduction; ``sparse_mode`` picks "elementwise" / "mxu" / "auto");
    stacked legacy TilePacks run the unrolled-grid baseline. Either way
    the whole bucket's matvec is ONE ``pallas_call`` with the diagonal
    epilogue fused in-kernel (DESIGN.md §3); shares mgk_pairs'
    ``fixed_iters``/``pcg_variant`` contract.

    ``gram_tile=(Bi, Bj)`` switches to Gram-tile execution (DESIGN.md
    §8): ``packs1``/``packs2`` are then PER-AXIS row-panel packs (Bi row
    graphs / Bj column graphs) while ``g1``/``g2`` stay the row-major
    pair-flattened batches of all B = Bi*Bj cross pairs — each matvec is
    one ``xmv_gram_tile`` launch reusing every row graph's panels across
    its Bj partners.

    ``precond="kron"`` solves with the Kronecker-factored approximate
    inverse (core/precond.py, DESIGN.md §9); ``factors1``/``factors2``
    optionally supply pack-time cached factors (per-axis under
    ``gram_tile``, mirroring the per-axis packs)."""
    sys_ = build_product_system(g1, g2, vertex_kernel)
    B, n = g1.adjacency.shape[0], g1.adjacency.shape[1]
    m = g2.adjacency.shape[1]
    diag = sys_.dx / sys_.vx
    matvec = _make_sparse_matvec(sys_, packs1, packs2, edge_kernel,
                                 sparse_mode, (B, n, m),
                                 gram_tile=gram_tile)
    papply = _make_precond_apply(precond, g1, g2, vertex_kernel,
                                 edge_kernel, (B, n, m),
                                 gram_tile=gram_tile, factors1=factors1,
                                 factors2=factors2, kron_rank=kron_rank,
                                 spd_margin=spd_margin)

    rhs = sys_.dx * sys_.qx
    sol = pcg_solve(matvec, rhs, diag, tol=tol, max_iter=max_iter,
                    fixed_iters=fixed_iters, variant=pcg_variant,
                    precond_apply=papply, guard=guard, fault=fault)
    values = jnp.sum(sys_.px * sol.x, axis=-1)
    nodal = sol.x.reshape(B, n, m) if return_nodal else None
    return MGKResult(values=values, iterations=sol.iterations,
                     converged=sol.converged, nodal=nodal,
                     matvec_pairs=sol.matvec_pairs, status=sol.status)


def mgk_pairs_sparse_segmented(
    g1: GraphBatch,
    g2: GraphBatch,
    packs1,                      # stacked (or per-axis) RowPanelPack
    packs2,
    vertex_kernel: BaseKernel = Constant(1.0),
    edge_kernel: BaseKernel = Constant(1.0),
    *,
    sparse_mode: str = "auto",
    tol: float = 1e-10,
    max_iter: int = 512,
    segment_size: int = 32,
    pad_multiple: int = 1,
    pcg_variant: str = "classic",
    gram_tile: tuple[int, int] | None = None,
    return_nodal: bool = False,
    precond: str = "jacobi",
    kron_rank: int = 2,
    factors1=None,
    factors2=None,
    guard: GuardSpec | bool | None = True,
    fault: MatvecFault | None = None,
    spd_margin=None,
) -> MGKResult:
    """:func:`mgk_pairs_sparse` solved with convergence-segmented PCG
    (``core/pcg.py:pcg_solve_segmented``, DESIGN.md §8): the solve runs
    in ``segment_size``-iteration scans and, between segments, pairs
    that converged RETIRE — the matvec batch is compacted by a
    gather/scatter remap of the packs and diagonal terms, so retired
    pairs stop paying matvecs instead of riding along masked.

    Host-driven (each segment is one compiled scan; this entry point
    itself is NOT jittable). With ``gram_tile=(Bi, Bj)`` the FULL
    rectangle runs the single-launch Gram-tile kernel; once pairs
    retire, the surviving (irregular) live set re-gathers per-pair packs
    from the per-axis packs and continues on the per-pair row-panel
    kernel — the usual tail is a handful of slow pairs, exactly where
    per-pair granularity is the right shape. Iterates agree with masked
    lockstep pair-for-pair; ``matvec_pairs`` is strictly smaller
    whenever any pair converges a segment early.

    ``precond="kron"``: the Kronecker preconditioner factors remap
    through the survivor gather/scatter like the packs do (per-axis
    factors expand to per-pair factors alongside the pack expansion),
    preserving the iterate-for-iterate lockstep contract under any
    ``precond=`` (DESIGN.md §9)."""
    from repro.kernels.ops import take_row_panel_pack

    sys_ = build_product_system(g1, g2, vertex_kernel)
    B, n = g1.adjacency.shape[0], g1.adjacency.shape[1]
    m = g2.adjacency.shape[1]
    diag = sys_.dx / sys_.vx
    matvec = _make_sparse_matvec(sys_, packs1, packs2, edge_kernel,
                                 sparse_mode, (B, n, m),
                                 gram_tile=gram_tile)
    kron = precond == "kron"
    if kron:
        # materialized HERE (not just inside the apply closure) because
        # select() re-gathers them for every compacted survivor batch
        factors1, factors2 = _resolve_kron_factors(g1, g2, gram_tile,
                                                   factors1, factors2)
    papply = _make_precond_apply(precond, g1, g2, vertex_kernel,
                                 edge_kernel, (B, n, m),
                                 gram_tile=gram_tile, factors1=factors1,
                                 factors2=factors2, kron_rank=kron_rank,
                                 spd_margin=spd_margin)

    def select(lanes):
        import numpy as np
        idx = jnp.asarray(np.asarray(lanes))
        sub_sys = ProductSystem(*(jnp.take(f, idx, axis=0)
                                  for f in sys_))
        if gram_tile is not None:
            # expand the per-axis packs to per-pair packs for the
            # irregular survivor set (pair b = bi*Bj + bj, row-major)
            Bi, Bj = gram_tile
            i1, i2 = idx // Bj, idx % Bj
            p1 = take_row_panel_pack(packs1, i1)
            p2 = take_row_panel_pack(packs2, i2)
        else:
            i1 = i2 = idx
            p1 = take_row_panel_pack(packs1, idx)
            p2 = take_row_panel_pack(packs2, idx)
        sub_mv = _make_sparse_matvec(sub_sys, p1, p2, edge_kernel,
                                     sparse_mode, (len(lanes), n, m))
        if not kron:
            return sub_mv
        # the preconditioner factors remap through the survivor gather
        # exactly like the packs (per-axis -> per-pair expansion
        # included); the per-pair scalars are recomputed from the same
        # gathered stats, so the compacted trajectory stays
        # iterate-for-iterate identical to lockstep
        from .precond import kron_apply, take_kron_factors
        sub_apply = kron_apply(take_kron_factors(factors1, i1),
                               take_kron_factors(factors2, i2),
                               vertex_kernel, edge_kernel,
                               (len(lanes), n, m), rank=kron_rank,
                               spd_margin=spd_margin)
        return sub_mv, sub_apply

    rhs = sys_.dx * sys_.qx
    sol = pcg_solve_segmented(matvec, rhs, diag, tol=tol,
                              max_iter=max_iter,
                              segment_size=segment_size,
                              variant=pcg_variant, select=select,
                              pad_multiple=pad_multiple,
                              precond_apply=papply, guard=guard,
                              fault=fault)
    values = jnp.sum(sys_.px * sol.x, axis=-1)
    nodal = sol.x.reshape(B, n, m) if return_nodal else None
    return MGKResult(values=values, iterations=sol.iterations,
                     converged=sol.converged, nodal=nodal,
                     matvec_pairs=sol.matvec_pairs, status=sol.status)
