"""Octile decomposition — the paper's two-level sparse storage (Sec. IV).

Level 1 (inter-tile): the adjacency/edge-label matrix is cut into t x t
square tiles ("octiles" for t = 8); only non-empty tiles are stored, in a
coordinate (COO-of-tiles) format sorted by (row_tile, col_tile) so that the
TPU block-sparse kernel owns each output block with a contiguous grid range
(the collision-free replacement for the paper's atomics, DESIGN.md §2).

Level 2 (intra-tile): each stored tile carries a multi-word occupancy
bitmap (bit q = i*t + j of word q // 64 is set iff element (i, j) of the
tile is nonzero) plus the packed nonzero values. A t = 8 octile fits one
uint64 word; t = 16 takes 4 words, t = 32 takes 16 — the tile edge is a
parameter throughout the stack (``TILE`` is only the paper's default). On
TPU the compact values are expanded into VMEM before compute, mirroring
the paper's "stored compact, expanded in shared memory".

All functions here are host-side (numpy) preprocessing; they run once per
graph per Gram block, so every per-tile loop is vectorized — at dataset
scale (millions of pair blocks) Python-level tile loops dominate the
preprocessing wall clock otherwise.

Their output feeds the device kernels as dense padded arrays + int32
coordinate lists.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "OctileSet",
    "octile_decompose",
    "count_nonempty_tiles",
    "tile_occupancy_histogram",
    "expand_octiles",
    "bitmap_popcounts",
    "bitmap_words",
    "feature_operands",
]

TILE = 8  # the paper's octile edge length (default, not a constraint)


def bitmap_words(tile: int) -> int:
    """Number of 64-bit words an occupancy bitmap of a t x t tile needs."""
    return -(-(tile * tile) // 64)


def bitmap_popcounts(bitmaps: np.ndarray) -> np.ndarray:
    """[K, W] uint64 multi-word bitmaps -> [K] per-tile popcounts.

    Vectorized via a uint8 view + ``np.unpackbits`` (endianness is
    irrelevant to a popcount). A 1-D [K] input (single-word bitmaps) is
    treated as [K, 1].
    """
    bitmaps = np.asarray(bitmaps, np.uint64)
    if bitmaps.ndim == 1:
        bitmaps = bitmaps[:, None]
    if bitmaps.shape[0] == 0:
        return np.zeros((0,), np.int64)
    bits = np.unpackbits(bitmaps.view(np.uint8), axis=1)
    return bits.sum(axis=1).astype(np.int64)


def _pack_bitmaps(nz: np.ndarray, tile: int) -> np.ndarray:
    """[K, t, t] bool occupancy -> [K, W] uint64 multi-word bitmaps."""
    K = nz.shape[0]
    W = bitmap_words(tile)
    flat = nz.reshape(K, tile * tile).astype(np.uint64)
    padded = np.zeros((K, W * 64), np.uint64)
    padded[:, :tile * tile] = flat
    weights = np.uint64(1) << np.arange(64, dtype=np.uint64)
    return (padded.reshape(K, W, 64) * weights).sum(axis=2, dtype=np.uint64)


@dataclasses.dataclass(frozen=True)
class OctileSet:
    """COO-of-octiles representation of one square matrix.

    Attributes:
      tile: tile edge length t.
      n_tiles_side: number of tile rows (= cols) of the padded matrix.
      coords: [K, 2] int32 (tile_row, tile_col) of non-empty tiles, sorted
        row-major.
      bitmaps: [K, W] uint64 occupancy bitmap words per tile
        (W = ceil(t^2 / 64); one word for the paper's t = 8).
      values_adj: [K, t, t] float32 dense tile values of the adjacency.
      values_lab: [K, t, t] float32 dense tile values of the edge labels.
      nnz: total nonzero element count.
    """

    tile: int
    n_tiles_side: int
    coords: np.ndarray
    bitmaps: np.ndarray
    values_adj: np.ndarray
    values_lab: np.ndarray
    nnz: int

    @property
    def n_nonempty(self) -> int:
        return int(self.coords.shape[0])

    @property
    def density(self) -> float:
        """Mean within-tile occupancy of the non-empty tiles."""
        if self.n_nonempty == 0:
            return 0.0
        pop = bitmap_popcounts(self.bitmaps)
        return float(pop.mean()) / (self.tile * self.tile)

    def padded(self, max_tiles: int) -> "OctileSet":
        """Pad the COO lists to a fixed length for jit-stable shapes."""
        K = self.n_nonempty
        if max_tiles < K:
            raise ValueError(f"max_tiles={max_tiles} < {K}")
        pad = max_tiles - K
        W = self.bitmaps.shape[1] if self.bitmaps.ndim == 2 \
            else bitmap_words(self.tile)
        return OctileSet(
            tile=self.tile,
            n_tiles_side=self.n_tiles_side,
            coords=np.concatenate(
                [self.coords, np.full((pad, 2), -1, np.int32)]),
            bitmaps=np.concatenate(
                [self.bitmaps.reshape(K, W),
                 np.zeros((pad, W), np.uint64)]),
            values_adj=np.concatenate(
                [self.values_adj,
                 np.zeros((pad, self.tile, self.tile), np.float32)]),
            values_lab=np.concatenate(
                [self.values_lab,
                 np.zeros((pad, self.tile, self.tile), np.float32)]),
            nnz=self.nnz,
        )


def _pad_to_tiles(mat: np.ndarray, tile: int) -> np.ndarray:
    n = mat.shape[0]
    n_pad = -(-n // tile) * tile
    if n_pad == n:
        return mat
    out = np.zeros((n_pad, n_pad), mat.dtype)
    out[:n, :n] = mat
    return out


def octile_decompose(adjacency: np.ndarray,
                     edge_labels: np.ndarray | None = None,
                     tile: int = TILE) -> OctileSet:
    """Decompose a square matrix into its non-empty t x t tiles."""
    adjacency = _pad_to_tiles(np.asarray(adjacency, np.float32), tile)
    if edge_labels is None:
        edge_labels = np.zeros_like(adjacency)
    edge_labels = _pad_to_tiles(np.asarray(edge_labels, np.float32), tile)
    nt = adjacency.shape[0] // tile
    # [nt, nt, t, t] view
    a4 = adjacency.reshape(nt, tile, nt, tile).transpose(0, 2, 1, 3)
    e4 = edge_labels.reshape(nt, tile, nt, tile).transpose(0, 2, 1, 3)
    occupied = (a4 != 0).any(axis=(2, 3))
    rows, cols = np.nonzero(occupied)
    order = np.lexsort((cols, rows))  # row-major: output-block contiguous
    rows, cols = rows[order], cols[order]
    vals_a = a4[rows, cols]
    vals_e = e4[rows, cols]
    nz = vals_a != 0
    return OctileSet(
        tile=tile,
        n_tiles_side=nt,
        coords=np.stack([rows, cols], axis=1).astype(np.int32),
        bitmaps=_pack_bitmaps(nz, tile),
        values_adj=vals_a.astype(np.float32),
        values_lab=vals_e.astype(np.float32),
        nnz=int(nz.sum()),
    )


def count_nonempty_tiles(adjacency: np.ndarray, tile: int = TILE) -> int:
    """Number of non-empty t x t tiles (the PBR objective, paper Eq. 3)."""
    adjacency = _pad_to_tiles(np.asarray(adjacency), tile)
    nt = adjacency.shape[0] // tile
    a4 = adjacency.reshape(nt, tile, nt, tile).transpose(0, 2, 1, 3)
    return int((a4 != 0).any(axis=(2, 3)).sum())


def tile_occupancy_histogram(adjacency: np.ndarray,
                             tile: int = TILE) -> np.ndarray:
    """Histogram over nonzeros-per-non-empty-tile (paper Fig. 7/8 input)."""
    adjacency = _pad_to_tiles(np.asarray(adjacency), tile)
    nt = adjacency.shape[0] // tile
    a4 = adjacency.reshape(nt, tile, nt, tile).transpose(0, 2, 1, 3)
    counts = (a4 != 0).sum(axis=(2, 3)).ravel()
    counts = counts[counts > 0]
    return np.bincount(counts, minlength=tile * tile + 1)


def feature_operands(values_adj, values_lab, edge_kernel, theta=None,
                     with_grad: bool = False):
    """Weighted MXU operands from packed tile values: ``w_r = a ∘ f_r(e)``
    and (``with_grad``) their per-parameter derivatives
    ``wg_{p,r} = a ∘ ∂f_r(e)/∂θ_p``.

    Shape contract: ``[..., t, t]`` tile stacks in, ``([..., R, t, t]``,
    ``[..., P, R, t, t] | None)`` out, P ordered by
    ``edge_kernel.param_names()``. Pure jnp on whatever arrays come in —
    the ONE implementation shared by host-side packing
    (``kernels.xmv_block_sparse.pack_row_panels``, numpy in / numpy out
    via ``np.asarray``) and the on-device repack of the differentiable
    path (``device_weighted_pack``), where ``theta`` carries traced
    hyperparameters and the result feeds the unchanged MXU kernel
    (DESIGN.md §7)."""
    import jax.numpy as jnp
    phi = edge_kernel.features_theta(values_lab, theta)  # [..., t, t, R]
    if phi is None:
        raise ValueError(
            f"{type(edge_kernel).__name__} has no feature expansion")
    w = jnp.moveaxis(jnp.asarray(values_adj)[..., None] * phi, -1, -3)
    wg = None
    if with_grad and edge_kernel.param_names():
        dphi = edge_kernel.dfeatures(values_lab, theta)
        stacks = [jnp.moveaxis(jnp.asarray(values_adj)[..., None] * d,
                               -1, -3)
                  for d in (dphi[n] for n in edge_kernel.param_names())]
        wg = jnp.stack(stacks, axis=-4)
    return w, wg


def expand_octiles(oset: OctileSet) -> tuple[np.ndarray, np.ndarray]:
    """Reconstruct the dense padded (adjacency, labels) from an OctileSet.

    Vectorized scatter into the [nt, nt, t, t] view (coords are unique, so
    fancy-index assignment is exact — no per-tile Python loop).
    """
    t, nt = oset.tile, oset.n_tiles_side
    a4 = np.zeros((nt, nt, t, t), np.float32)
    e4 = np.zeros((nt, nt, t, t), np.float32)
    real = oset.coords[:, 0] >= 0       # skip padded() slots
    rows, cols = oset.coords[real, 0], oset.coords[real, 1]
    a4[rows, cols] = oset.values_adj[real]
    e4[rows, cols] = oset.values_lab[real]
    a = a4.transpose(0, 2, 1, 3).reshape(nt * t, nt * t)
    e = e4.transpose(0, 2, 1, 3).reshape(nt * t, nt * t)
    return a, e
