"""On-the-fly Kronecker product matrix-vector multiplication (XMV).

This module holds the pure-JAX (jnp) implementations of the paper's
Algorithm 2 — the hotspot of the CG solve:

    y[ii'] = sum_{jj'}  A[i,j] * A'[i',j'] * kappa_e(E[i,j], E'[i',j'])
                        * p[jj']

Variants:

* :func:`xmv_full`        — materializes the [n,n,m,m] product; exact oracle
                            for small graphs (the "naive" baseline column of
                            paper Table I, used for validation + benchmarks).
* :func:`xmv_elementwise` — streams over j-chunks, never materializing more
                            than O(n m^2 c) — the jnp analogue of the
                            paper-faithful on-the-fly primitive. The Pallas
                            production kernel (kernels/xmv_dense.py) is the
                            TPU version of this.
* :func:`xmv_lowrank`     — beyond-paper MXU path: with a symmetric feature
                            expansion kappa(x,y) = sum_r phi_r(x) phi_r(y),
                            XMV becomes  y = sum_r (A .* phi_r(E)) P
                            (A' .* phi_r(E'))^T — pure matmuls.

All functions take and return the product-space vector reshaped as a
[n, m] matrix P (row j indexes graph-1 nodes, column j' graph-2 nodes) and
are batched with vmap at the call site.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .base_kernels import BaseKernel

__all__ = ["xmv_full", "xmv_gram_full", "xmv_elementwise", "xmv_lowrank",
           "weighted_operands", "weighted_operand_grads",
           "kron_precond_dense"]


def _kappa(edge_kernel: BaseKernel, x, y, theta):
    """kappa via ``apply`` when a theta override rides along (traced
    hyperparameters, DESIGN.md §7), else the plain static-param call."""
    if theta is None:
        return edge_kernel(x, y)
    return edge_kernel.apply(x, y, theta)


def xmv_full(A, E, Ap, Ep, P, edge_kernel: BaseKernel, theta=None):
    """Exact XMV via full product materialization. O(n^2 m^2) memory."""
    # K[i, j, ip, jp] = kappa(E[i, j], Ep[ip, jp])
    K = _kappa(edge_kernel, E[:, :, None, None], Ep[None, None, :, :],
               theta)
    W = A[:, :, None, None] * Ap[None, None, :, :] * K
    return jnp.einsum("ijkl,jl->ik", W, P)


def xmv_gram_full(A1, E1, A2, E2, P, edge_kernel: BaseKernel, theta=None):
    """Cross-pair oracle for Gram-tile execution: every (i, j) pair of
    a row axis ``A1/E1`` [Bi, n, n] against a column axis ``A2/E2``
    [Bj, m, m], applied to ``P`` [Bi, Bj, n, m] -> [Bi, Bj, n, m].

    A doubly-vmapped :func:`xmv_full` — O(Bi*Bj*n^2*m^2) memory, the
    validation/bench reference for ``kernels.xmv_gram_tile`` only."""
    one = lambda a, e, ap, ep, p: xmv_full(a, e, ap, ep, p,     # noqa
                                           edge_kernel, theta=theta)
    inner = jax.vmap(one, in_axes=(None, None, 0, 0, 0))    # over Bj
    return jax.vmap(inner, in_axes=(0, 0, None, None, 0))(A1, E1, A2,
                                                          E2, P)


def xmv_elementwise(A, E, Ap, Ep, P, edge_kernel: BaseKernel,
                    chunk: int = 8, theta=None):
    """Paper-faithful streaming XMV: scan over length-``chunk`` column
    blocks of (A, E), regenerating kappa products on the fly. Peak temp
    memory O(chunk * n * m^2) instead of O(n^2 m^2).

    ``chunk`` is a memory/throughput knob, not a correctness contract:
    when it does not divide ``n`` it is clamped to the largest divisor of
    ``n`` that fits, so arbitrary bucket sizes work."""
    n, m = A.shape[0], Ap.shape[0]
    if n % chunk:
        chunk = max(c for c in range(1, min(chunk, n) + 1) if n % c == 0)

    def body(carry, j0):
        y = carry
        Aj = jax.lax.dynamic_slice(A, (0, j0), (n, chunk))      # [n, c]
        Ej = jax.lax.dynamic_slice(E, (0, j0), (n, chunk))      # [n, c]
        Pj = jax.lax.dynamic_slice(P, (j0, 0), (chunk, m))      # [c, m]
        # kappa between this chunk's labels and ALL of E': [n, c, m, m]
        K = _kappa(edge_kernel, Ej[:, :, None, None],
                   Ep[None, None, :, :], theta)
        W = Aj[:, :, None, None] * Ap[None, None, :, :] * K
        y = y + jnp.einsum("ickl,cl->ik", W, Pj)
        return y, None

    y0 = jnp.zeros((n, m), P.dtype)
    y, _ = jax.lax.scan(body, y0, jnp.arange(0, n, chunk))
    return y


def kron_precond_dense(f1, f2, a, b):
    """Dense oracle for the Kronecker-factored preconditioner
    (DESIGN.md §9): materialize one pair's ``M^{-1}`` as the
    [n*m, n*m] matrix

        M^{-1} = a · diag(dinv ⊗ dinv') + b · (S ⊗ S')

    from single-graph :class:`~repro.core.precond.KronFactors` ``f1``
    (row graph, [n, ...] fields) and ``f2`` (column graph) and the
    pair's scalar coefficients (``precond.kron_scalars``). Row-major
    product flattening (ii' = i·m + i'), matching the solver's
    ``reshape``-based application, so ``oracle @ r`` must equal
    ``kron_apply(r)`` exactly — the validation/bench reference only
    (O(n²m²) memory), never a production path."""
    dd = (f1.dinv[:, None] * f2.dinv[None, :]).reshape(-1)
    return a * jnp.diag(dd) + b * jnp.kron(f1.s, f2.s)


def weighted_operands(A, E, edge_kernel: BaseKernel, theta=None):
    """[R, n, n] stack of (A .* phi_r(E)) for the low-rank path."""
    phi = edge_kernel.features_theta(E, theta) if theta is not None \
        else edge_kernel.features(E)  # [n, n, R]
    if phi is None:
        raise ValueError(
            f"{type(edge_kernel).__name__} has no feature expansion; use the"
            " elementwise path")
    return jnp.einsum("ij,ijr->rij", A, phi)


def weighted_operand_grads(A, E, edge_kernel: BaseKernel,
                           theta=None) -> dict:
    """Per-parameter [R, n, n] stacks of (A .* ∂phi_r(E)/∂θ) — the
    low-rank path's analytic operand derivatives (DESIGN.md §7)."""
    dphi = edge_kernel.dfeatures(E, theta)
    return {name: jnp.einsum("ij,ijr->rij", A, d)
            for name, d in dphi.items()}


def xmv_lowrank(A, E, Ap, Ep, P, edge_kernel: BaseKernel):
    """Beyond-paper MXU 'sandwich' XMV (DESIGN.md §2): two dense matmuls
    per feature rank. FLOPs 2R(n^2 m + n m^2) vs the elementwise path's
    X n^2 m^2 — asymptotically cheaper AND MXU-eligible."""
    WA = weighted_operands(A, E, edge_kernel)     # [R, n, n]
    WAp = weighted_operands(Ap, Ep, edge_kernel)  # [R, m, m]
    return jnp.einsum("rij,jl,rkl->ik", WA, P, WAp)


def xmv_lowrank_precomputed(WA, WAp, P):
    """Low-rank XMV with pre-weighted operands (amortized across the CG
    iterations of one solve — the weighting is loop-invariant)."""
    return jnp.einsum("rij,jl,rkl->ik", WA, P, WAp)


@partial(jax.jit, static_argnames=("edge_kernel", "method", "chunk"))
def xmv(A, E, Ap, Ep, P, edge_kernel: BaseKernel, method: str = "full",
        chunk: int = 8):
    """Dispatching convenience wrapper (single pair)."""
    if method == "full":
        return xmv_full(A, E, Ap, Ep, P, edge_kernel)
    if method == "elementwise":
        return xmv_elementwise(A, E, Ap, Ep, P, edge_kernel, chunk=chunk)
    if method == "lowrank":
        return xmv_lowrank(A, E, Ap, Ep, P, edge_kernel)
    raise ValueError(f"unknown method {method!r}")
