"""Graph reordering for inter-tile sparsity (paper Sec. IV-A).

Implements the three orderings the paper retains:

* :func:`pbr_order` — partition-based reordering: recursive balanced
  bipartitioning with Fiduccia–Mattheyses refinement, targeting the paper's
  Eq. (3) objective (minimize the number of connected part pairs = non-empty
  off-diagonal octiles). Parts of size ``tile`` imply the ordering.
* :func:`rcm_order` — Reverse Cuthill–McKee bandwidth reduction.
* :func:`morton_order` — Morton (Z-curve) order for graphs whose nodes are
  embedded in Euclidean space (e.g. 3D molecular structures).

All host-side numpy: reordering is linear-ish preprocessing amortized over
hundreds of quadratic-cost kernel evaluations (paper Sec. IV "Reordering
overhead").
"""
from __future__ import annotations

import numpy as np

from .octile import count_nonempty_tiles

__all__ = ["rcm_order", "morton_order", "pbr_order", "best_order"]


def _adjacency_lists(adjacency: np.ndarray) -> list[np.ndarray]:
    a = np.asarray(adjacency)
    return [np.nonzero(a[i])[0] for i in range(a.shape[0])]


def _pseudo_peripheral(adj: list[np.ndarray], degrees: np.ndarray,
                       component: np.ndarray) -> int:
    """Find a pseudo-peripheral vertex of one connected component by
    repeated BFS (George–Liu heuristic)."""
    root = int(component[np.argmin(degrees[component])])
    last_ecc = -1
    for _ in range(8):
        # BFS levels from root
        level = {root: 0}
        frontier = [root]
        depth = 0
        while frontier:
            nxt = []
            for u in frontier:
                for v in adj[u]:
                    v = int(v)
                    if v not in level:
                        level[v] = depth + 1
                        nxt.append(v)
            if nxt:
                depth += 1
            frontier = nxt
        if depth <= last_ecc:
            break
        last_ecc = depth
        last_level = [u for u, l in level.items() if l == depth]
        root = min(last_level, key=lambda u: degrees[u])
    return root


def rcm_order(adjacency: np.ndarray) -> np.ndarray:
    """Reverse Cuthill–McKee ordering. Returns perm with perm[k] = old index
    of the k-th node in the new order."""
    a = np.asarray(adjacency)
    n = a.shape[0]
    adj = _adjacency_lists(a)
    degrees = np.array([len(x) for x in adj])
    visited = np.zeros(n, bool)
    order: list[int] = []
    while len(order) < n:
        comp_seed = int(np.nonzero(~visited)[0][0])
        # collect the component
        comp, stack = [], [comp_seed]
        seen = {comp_seed}
        while stack:
            u = stack.pop()
            comp.append(u)
            for v in adj[u]:
                v = int(v)
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        comp = np.array(comp)
        root = _pseudo_peripheral(adj, degrees, comp)
        # Cuthill–McKee BFS with degree-sorted neighbor visiting
        queue = [root]
        visited[root] = True
        qi = 0
        while qi < len(queue):
            u = queue[qi]
            qi += 1
            order.append(u)
            nbrs = [int(v) for v in adj[u] if not visited[v]]
            nbrs.sort(key=lambda v: degrees[v])
            for v in nbrs:
                visited[v] = True
                queue.append(v)
    return np.array(order[::-1], dtype=np.int64)  # reverse CM


def morton_order(coords: np.ndarray, bits: int = 10) -> np.ndarray:
    """Morton (Z-)curve ordering of spatially embedded nodes.

    Args:
      coords: [n, d] node coordinates, d <= 3.
    """
    coords = np.asarray(coords, np.float64)
    n, d = coords.shape
    lo = coords.min(axis=0)
    span = np.maximum(coords.max(axis=0) - lo, 1e-12)
    q = np.minimum(((coords - lo) / span * (2 ** bits - 1)).astype(np.uint64),
                   2 ** bits - 1)
    codes = np.zeros(n, np.uint64)
    for bit in range(bits):
        for dim in range(d):
            codes |= ((q[:, dim] >> np.uint64(bit)) & np.uint64(1)) << \
                np.uint64(bit * d + dim)
    return np.argsort(codes, kind="stable")


# ----------------------------------------------------------------------
# Partition-based reordering (PBR)
# ----------------------------------------------------------------------

def _fm_refine(adj: list[np.ndarray], side: np.ndarray, max_imbalance: int,
               passes: int = 8, rng: np.random.Generator | None = None
               ) -> np.ndarray:
    """Fiduccia–Mattheyses refinement of a bipartition.

    ``side`` is a bool array; the balance constraint keeps
    ``|#True - target_true| <= max_imbalance``.
    Minimizes the edge cut (a consistent proxy of paper Eq. 3 at the
    bipartition level: fewer cut edges -> fewer connected part pairs after
    recursion).
    """
    n = len(side)
    side = side.copy()
    target_true = int(side.sum())
    for _ in range(passes):
        locked = np.zeros(n, bool)
        # gain = external degree - internal degree
        gains = np.zeros(n, np.int64)
        for u in range(n):
            for v in adj[u]:
                gains[u] += 1 if side[v] != side[u] else -1
        best_cut_delta, cum_delta = 0, 0
        moves: list[int] = []
        count_true = target_true
        best_prefix = 0
        for _step in range(n):
            cand = np.nonzero(~locked)[0]
            if len(cand) == 0:
                break
            # balance-feasible candidates
            feas = []
            for u in cand:
                new_true = count_true + (-1 if side[u] else 1)
                if abs(new_true - target_true) <= max_imbalance:
                    feas.append(u)
            if not feas:
                break
            feas = np.array(feas)
            u = int(feas[np.argmax(gains[feas])])
            cum_delta -= gains[u]
            moves.append(u)
            locked[u] = True
            count_true += (-1 if side[u] else 1)
            side[u] = ~side[u]
            for v in adj[u]:
                v = int(v)
                if side[v] == side[u]:
                    gains[v] -= 2
                else:
                    gains[v] += 2
            if cum_delta < best_cut_delta:
                best_cut_delta = cum_delta
                best_prefix = len(moves)
        # roll back moves after the best prefix
        for u in moves[best_prefix:]:
            side[u] = ~side[u]
        if best_prefix == 0:
            break
    return side


def _grow_bipartition(adj: list[np.ndarray], nodes: np.ndarray,
                      half: int) -> np.ndarray:
    """BFS graph-growing initial bipartition of ``nodes`` (local indices)."""
    n = len(nodes)
    side = np.zeros(n, bool)
    pos = {int(g): i for i, g in enumerate(nodes)}
    degree = np.array([sum(1 for v in adj[g] if int(v) in pos)
                       for g in nodes])
    seen = np.zeros(n, bool)
    grown = 0
    while grown < half:
        seeds = np.nonzero(~seen)[0]
        root = int(seeds[np.argmin(degree[seeds])])
        queue, seen[root] = [root], True
        qi = 0
        while qi < len(queue) and grown < half:
            u = queue[qi]
            qi += 1
            side[u] = True
            grown += 1
            for gv in adj[int(nodes[u])]:
                lv = pos.get(int(gv))
                if lv is not None and not seen[lv]:
                    seen[lv] = True
                    queue.append(lv)
    return side


def pbr_order(adjacency: np.ndarray, tile: int = 8,
              fm_passes: int = 8, restarts: int = 3) -> np.ndarray:
    """Partition-based reordering (paper Sec. IV-A, after [8]).

    Recursive balanced bipartitioning with boundary-FM refinement and tight
    balance (the paper's "custom weight distribution ... to promote equally
    sized parts"), recursing until parts have at most ``tile`` vertices. The
    concatenated parts imply the node order; a final exact-balance step
    fixes any residual imbalance (the paper's extra FM-based refinement).

    Multi-start: the recursive bipartitioning is seeded from ``restarts``
    different growth roots and the ordering with the fewest non-empty
    tiles (the objective itself, paper Eq. 3) is kept — the cheap stand-in
    for the hypergraph partitioner's randomized coarsening in [8]. The
    identity permutation competes as a zeroth candidate, so PBR is
    never-worse-than-natural BY CONSTRUCTION (the invariant the property
    suite asserts, tests/test_reorder.py): graphs whose natural order is
    already tile-optimal (banded molecules, pre-ordered inputs) keep it.
    """
    a = np.asarray(adjacency)
    n = a.shape[0]
    adj = _adjacency_lists(a)

    def one_run(seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        order: list[int] = []

        def recurse(nodes: np.ndarray) -> None:
            if len(nodes) <= tile:
                order.extend(int(u) for u in nodes)
                return
            # split into sizes that stay multiples of tile where possible
            # (custom weight distribution promoting equally sized tiles)
            n_tiles = -(-len(nodes) // tile)
            left_tiles = n_tiles // 2
            half = left_tiles * tile
            if seed == 0:
                sub_nodes = nodes
            else:  # randomized growth root for restarts
                sub_nodes = np.array(sorted(
                    nodes, key=lambda u: rng.random()))
            side = _grow_bipartition(adj, sub_nodes, half)
            # map side back onto `nodes` order
            side_map = dict(zip((int(u) for u in sub_nodes), side))
            side = np.array([side_map[int(u)] for u in nodes])
            # restrict adjacency to this subgraph for FM
            pos = {int(g): i for i, g in enumerate(nodes)}
            sub_adj = [np.array([pos[int(v)] for v in adj[int(g)]
                                 if int(v) in pos], dtype=np.int64)
                       for g in nodes]
            side = _fm_refine(sub_adj, side, max_imbalance=0,
                              passes=fm_passes)
            recurse(nodes[side])
            recurse(nodes[~side])

        recurse(np.arange(n))
        return np.array(order, dtype=np.int64)

    best_perm = np.arange(n, dtype=np.int64)   # identity: the floor
    best_score = count_nonempty_tiles(a, tile)
    for seed in range(restarts):
        perm = one_run(seed)
        score = count_nonempty_tiles(a[np.ix_(perm, perm)], tile)
        if score < best_score:
            best_perm, best_score = perm, score
    return best_perm


def best_order(adjacency: np.ndarray, tile: int = 8,
               coords: np.ndarray | None = None
               ) -> tuple[np.ndarray, str, int]:
    """Try natural / RCM / PBR (and Morton when coords given); return the
    permutation with the fewest non-empty tiles — the adaptive policy the
    production pipeline uses."""
    a = np.asarray(adjacency)
    candidates: dict[str, np.ndarray] = {
        "natural": np.arange(a.shape[0]),
        "rcm": rcm_order(a),
        "pbr": pbr_order(a, tile=tile),
    }
    if coords is not None:
        candidates["morton"] = morton_order(coords)
    scores = {
        name: count_nonempty_tiles(a[np.ix_(p, p)], tile)
        for name, p in candidates.items()
    }
    name = min(scores, key=scores.get)
    return candidates[name], name, scores[name]
