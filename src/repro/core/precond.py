"""Kronecker-factored approximate-inverse preconditioning (DESIGN.md §9).

PRs 1-4 drove the cost of one PCG matvec down; what remains is how MANY
matvecs a solve needs, which the paper's plain Jacobi preconditioner
(Algorithm 1 line 2, ``M = diag(A) = D_x V_x^{-1}``) leaves on the
table: it ignores the tensor-product structure of the generalized
Laplacian entirely. This module builds the structured alternative.

Derivation (§9.1). The product system is

    A = D_x V_x^{-1} - A_x ∘ E_x,      D_x = D ⊗ D'

with ``D = diag(d)`` the per-graph degree matrices. Factoring the
diagonal out and expanding the inverse as a Neumann series,

    A^{-1} = (I - V_x D_x^{-1} (A_x ∘ E_x))^{-1} V_x D_x^{-1}
           ≈ V_x D_x^{-1} + V_x D_x^{-1} (A_x ∘ E_x) V_x D_x^{-1} + ...

Under the mean-field closure ``V_x ≈ v̄ I``, ``E_x ≈ κ̄`` (the label
statistics of the pair), the first-order truncation IS a rank-2
Kronecker sum of per-graph factors:

    M^{-1} = a (D^{-1} ⊗ D'^{-1}) + b (S ⊗ S'),
    S = D^{-1} A D^{-1},   a = v̄,   b = v̄² κ̄.

Why it works (§9.1): in the symmetrized space the Jacobi-preconditioned
spectrum is ``1 - μ λᵢ μⱼ`` with ``λᵢ μⱼ`` the eigenvalue products of
the two normalized adjacencies ``Ã = D^{-1/2} A D^{-1/2}`` and
``μ = v̄ κ̄``; the rank-2 preconditioner maps it to
``(1 - μx)(a + bx) ≈ 1 - μ²x²`` — the condition number drops by
``(1 + μρρ')²``, which for the near-critical small-``q`` regime the
paper's datasets live in is the difference between tens and hundreds of
CG iterations.

SPD guarantee. ``S ⊗ S'`` alone is indefinite (adjacency spectra are
two-sided), so ``b`` is clamped with each graph's PACK-TIME spectral
bound ``σ = ρ(Ã) ≤ max_i Σ_j |A_ij| / sqrt(d_i d_j)`` (Gershgorin):

    b ≤ spd_margin · a / (σ σ')   =>   M^{-1} ≻ 0.

Everything per-graph — ``S``, ``1/d``, ``σ``, the label means — is a
pure function of (adjacency, degrees, labels): computed once at pack
time, cached on :class:`~repro.distributed.gram.GraphPackCache`
alongside the octile packs, and stacked per pair batch or PER AXIS for
Gram-tile execution (mirroring ``stacked_axis``). The pair-level
scalars ``a``/``b`` are two kernel evaluations on label means.

Application cost. ``M^{-1} r`` on the reshaped residual is one
elementwise product plus one batched ``[n,n] @ X @ [m,m]`` sandwich —
two small dense matmuls per pair, exactly the MXU-friendly shape this
codebase is built around; no new sparse format, no extra HBM-resident
operator. The preconditioner changes ONLY the solve trajectory, never
the solution, so the adjoint VJP (core/adjoint.py) reuses the identical
SPD ``M^{-1}`` for its backward solve and gradients are untouched.

The dense oracle lives in ``core/xmv.py:kron_precond_dense`` (the
validation reference of tests/test_precond.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

__all__ = ["KronFactors", "kron_factors", "kron_factor_arrays",
           "kron_scalars", "kron_apply", "kron_apply_gram",
           "take_kron_factors", "stack_kron_factors"]

# floor for v̄ (keeps a > 0 for degenerate/padded pairs) and for the
# σσ' denominator of the SPD clamp (zero-edge graphs have σ = 0)
_VBAR_FLOOR = 1e-6
_SIGMA_FLOOR = 1e-6
# default SPD safety margin: b ≤ margin · a / (σ σ')
SPD_MARGIN = 0.95


class KronFactors(NamedTuple):
    """Per-graph Kronecker-preconditioner factors (any leading batch
    axes; the Gram driver caches the per-graph [n, ...] slices and
    stacks them per pair batch or per Gram-tile axis).

    s:     [..., n, n] ``D^{-1} A D^{-1}`` — the rank-2 term's factor.
    dinv:  [..., n]    ``1 / d`` — the rank-1 (diagonal) factor.
    sigma: [...]       Gershgorin bound on ``ρ(D^{-1/2} A D^{-1/2})``,
                       the pack-time ingredient of the SPD clamp.
    emean: [...]       mean edge label over nonzero adjacency entries.
    vmean: [...]       node-mask-weighted mean vertex label.

    The label means feed the pair-time mean-field scalars
    (:func:`kron_scalars`); they are statistics, not operands — the
    preconditioner only shapes the solve trajectory, so a crude closure
    costs iterations, never correctness.
    """
    s: jnp.ndarray
    dinv: jnp.ndarray
    sigma: jnp.ndarray
    emean: jnp.ndarray
    vmean: jnp.ndarray

    @property
    def n(self) -> int:
        return self.s.shape[-1]


def kron_factor_arrays(adjacency, degrees, edge_labels, vertex_labels,
                       node_mask) -> KronFactors:
    """Factors from raw graph arrays (works batched or per-graph, jnp or
    numpy in / jnp out). The ONE implementation shared by the in-trace
    path (:func:`kron_factors` on a GraphBatch) and the Gram driver's
    host-side pack cache."""
    A = jnp.asarray(adjacency)
    d = jnp.asarray(degrees)
    dinv = 1.0 / d
    s = dinv[..., :, None] * A * dinv[..., None, :]
    # ρ(Ã) bound via the SIMILAR matrix D^{-1} A (same spectrum as the
    # symmetrized Ã = D^{-1/2} A D^{-1/2}): ρ ≤ ||D^{-1}|A|||_∞
    # = max_i Σ_j |A_ij| / d_i. With the paper's degrees
    # d_i = Σ_j A_ij + q_i this is 1 - min_i q_i/d_i < 1 — far tighter
    # than Gershgorin on Ã itself, whose √(d_i d_j) cross terms
    # overshoot past 1 on degree-heterogeneous graphs (padded rows:
    # A = 0, d = 1 contribute 0)
    sigma = jnp.max(jnp.sum(jnp.abs(A), axis=-1) * dinv, axis=-1)
    nz = (A != 0).astype(d.dtype)
    cnt = jnp.sum(nz, axis=(-2, -1))
    emean = jnp.sum(jnp.asarray(edge_labels) * nz, axis=(-2, -1)) \
        / jnp.maximum(cnt, 1.0)
    mask = jnp.asarray(node_mask)
    vmean = jnp.sum(jnp.asarray(vertex_labels) * mask, axis=-1) \
        / jnp.maximum(jnp.sum(mask, axis=-1), 1.0)
    return KronFactors(s=s, dinv=dinv, sigma=sigma, emean=emean,
                       vmean=vmean)


def kron_factors(g) -> KronFactors:
    """Factors for every graph of a :class:`GraphBatch` (leading [B]
    axis on each field). Pure jnp — safe inside jit traces, so the
    non-cached entry points (``mgk_pairs``/``mgk_pairs_sparse`` without
    driver factors) build factors on the fly at O(B n²) cost, amortized
    over the whole solve."""
    return kron_factor_arrays(g.adjacency, g.degrees, g.edge_labels,
                              g.vertex_labels, g.node_mask)


def take_kron_factors(f: KronFactors, indices) -> KronFactors:
    """Gather stacked factors along the leading batch axis — the
    segmented-PCG pair-retirement remap and the Gram-tile -> per-pair
    expansion, mirroring ``ops.take_row_panel_pack``."""
    idx = jnp.asarray(indices)
    return KronFactors(*(jnp.take(x, idx, axis=0) for x in f))


def stack_kron_factors(factors: list[KronFactors]) -> KronFactors:
    """Stack per-graph factors to a leading [B] axis (same-bucket
    graphs => same shapes) — the pack-cache stacking hook."""
    return KronFactors(*(jnp.stack([getattr(f, name) for f in factors])
                         for name in KronFactors._fields))


def kron_scalars(f1: KronFactors, f2: KronFactors, vertex_kernel,
                 edge_kernel, spd_margin: float | None = None,
                 outer: bool = False):
    """Pair-level mean-field scalars ``(a, b)`` of the §9 expansion:
    ``a = v̄``, ``b = min(v̄² κ̄, spd_margin · a / (σ σ'))``.

    ``v̄``/``κ̄`` are the base kernels evaluated on the factors' label
    means — two scalar kernel calls per pair. The clamp is the SPD
    certificate: with ``b σ σ' < a`` every eigenvalue of
    ``a D_x^{-1} + b S ⊗ S'`` is positive (§9.2). ``outer=True``
    broadcasts [Bi] row factors against [Bj] column factors to [Bi, Bj]
    scalars (Gram-tile execution).

    ``spd_margin`` may be a traced scalar (resolved at trace time, so a
    margin override reaches already-jitted entry points as an ARGUMENT
    instead of a module-global monkeypatch that cached traces would
    ignore). None = the module default. A NEGATIVE margin is the
    certificate-FAILURE injection seam of the fault harness
    (distributed/faults.py, DESIGN.md §10): the clamp is bypassed and
    ``b = |margin| · a / (σ σ')`` is forced outright — ``|margin| >= 1``
    makes ``M^{-1}`` indefinite, which the PCG guards must catch as a
    (r, M^{-1} r) < 0 breakdown."""
    vm1, em1, s1 = f1.vmean, f1.emean, f1.sigma
    if outer:
        vm1, em1, s1 = vm1[..., None], em1[..., None], s1[..., None]
    vbar = jnp.maximum(vertex_kernel(vm1, f2.vmean), _VBAR_FLOOR)
    kbar = jnp.maximum(edge_kernel(em1, f2.emean), 0.0)
    a = vbar
    margin = jnp.asarray(SPD_MARGIN if spd_margin is None else spd_margin)
    cap = jnp.abs(margin) * a / jnp.maximum(s1 * f2.sigma, _SIGMA_FLOOR)
    b = jnp.where(margin < 0, cap, jnp.minimum(vbar * vbar * kbar, cap))
    return a, b


def _check_rank(rank: int) -> None:
    if rank not in (1, 2):
        raise ValueError(f"kron_rank must be 1 or 2, got {rank}")


def kron_apply(f1: KronFactors, f2: KronFactors, vertex_kernel,
               edge_kernel, shape: tuple[int, int, int], *,
               rank: int = 2, spd_margin: float | None = None):
    """``apply(r) -> M^{-1} r`` over a per-pair batch: ``f1``/``f2`` are
    stacked [B]-leading factors aligned with the pair batch, ``r`` is
    the [B, n*m] residual. rank=1 keeps only the diagonal Kronecker term
    (mean-field Jacobi — the ablation arm); rank=2 adds the
    ``S ⊗ S'`` sandwich: one batched ``[n,n] @ X @ [m,m]`` contraction
    per application."""
    _check_rank(rank)
    B, n, m = shape
    a, b = kron_scalars(f1, f2, vertex_kernel, edge_kernel,
                        spd_margin=spd_margin)
    dd = f1.dinv[:, :, None] * f2.dinv[:, None, :]          # [B, n, m]

    def apply(r):
        X = r.reshape(B, n, m)
        Y = a[:, None, None] * (dd * X)
        if rank >= 2:
            Y = Y + b[:, None, None] * jnp.einsum(
                "bij,bjk,blk->bil", f1.s, X, f2.s)
        return Y.reshape(B, n * m)

    return apply


def kron_apply_gram(f1: KronFactors, f2: KronFactors, vertex_kernel,
                    edge_kernel, shape: tuple[int, int, int, int], *,
                    rank: int = 2, spd_margin: float | None = None):
    """Gram-tile variant: PER-AXIS factors ([Bi] row graphs / [Bj]
    column graphs, mirroring the per-axis packs of ``stacked_axis``),
    applied to the row-major pair-flattened [Bi*Bj, n*m] residual. Each
    axis's ``S`` factor exists once and the einsum contracts it against
    all partners — the factor analog of the Gram-tile kernel's
    cross-pair panel reuse."""
    _check_rank(rank)
    Bi, Bj, n, m = shape
    a, b = kron_scalars(f1, f2, vertex_kernel, edge_kernel,
                        spd_margin=spd_margin, outer=True)   # [Bi, Bj]
    dd = f1.dinv[:, None, :, None] * f2.dinv[None, :, None, :]

    def apply(r):
        X = r.reshape(Bi, Bj, n, m)
        Y = a[..., None, None] * (dd * X)
        if rank >= 2:
            Y = Y + b[..., None, None] * jnp.einsum(
                "pij,pqjk,qlk->pqil", f1.s, X, f2.s)
        return Y.reshape(Bi * Bj, n * m)

    return apply
