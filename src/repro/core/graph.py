"""Graph representations for the marginalized graph kernel solver.

Two levels:

* :class:`Graph` — host-side (numpy) labeled weighted graph, the unit the
  data pipeline produces. Variable size.
* :class:`GraphBatch` — device-side (jnp) fixed-shape padded batch, the unit
  the solver consumes. Padding convention (see DESIGN.md §6): adjacency and
  edge labels are zero-padded, stopping probability ``q`` is zero-padded,
  degrees are one-padded, and the node mask marks real nodes. With that
  convention padded rows of the product system decouple into ``x_pad = 0``
  and contribute nothing to the kernel value.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

import jax.numpy as jnp

__all__ = ["Graph", "GraphBatch", "pad_graphs", "batch_from_graphs"]


@dataclasses.dataclass(frozen=True)
class Graph:
    """A host-side labeled, weighted, undirected graph.

    Attributes:
      adjacency: ``[n, n]`` float array of edge weights, symmetric,
        zero diagonal unless self loops are intended.
      edge_labels: ``[n, n]`` float array of edge labels; only entries where
        ``adjacency != 0`` are meaningful.
      vertex_labels: ``[n]`` array of vertex labels (float or int codes).
      start_prob: ``[n]`` starting probability of the random walk
        (defaults to uniform ``1/n``).
      stop_prob: ``[n]`` stopping probability of the random walk
        (defaults to a constant, paper uses values as small as 0.0005).
    """

    adjacency: np.ndarray
    edge_labels: np.ndarray
    vertex_labels: np.ndarray
    start_prob: np.ndarray
    stop_prob: np.ndarray

    @property
    def n_nodes(self) -> int:
        return int(self.adjacency.shape[0])

    @property
    def n_edges(self) -> int:
        return int(np.count_nonzero(np.triu(self.adjacency, k=1)))

    @staticmethod
    def create(
        adjacency: np.ndarray,
        edge_labels: np.ndarray | None = None,
        vertex_labels: np.ndarray | None = None,
        start_prob: np.ndarray | None = None,
        stop_prob: float | np.ndarray = 0.05,
    ) -> "Graph":
        adjacency = np.asarray(adjacency, dtype=np.float32)
        n = adjacency.shape[0]
        if adjacency.shape != (n, n):
            raise ValueError(f"adjacency must be square, got {adjacency.shape}")
        if not np.allclose(adjacency, adjacency.T):
            raise ValueError("adjacency must be symmetric (undirected graph)")
        if edge_labels is None:
            edge_labels = np.zeros_like(adjacency)
        edge_labels = np.asarray(edge_labels, dtype=np.float32)
        if vertex_labels is None:
            vertex_labels = np.zeros((n,), dtype=np.float32)
        vertex_labels = np.asarray(vertex_labels, dtype=np.float32)
        if start_prob is None:
            start_prob = np.full((n,), 1.0 / max(n, 1), dtype=np.float32)
        start_prob = np.asarray(start_prob, dtype=np.float32)
        if np.isscalar(stop_prob) or np.ndim(stop_prob) == 0:
            stop_prob = np.full((n,), float(stop_prob), dtype=np.float32)
        stop_prob = np.asarray(stop_prob, dtype=np.float32)
        return Graph(adjacency, edge_labels, vertex_labels, start_prob, stop_prob)

    def permuted(self, perm: np.ndarray) -> "Graph":
        """Return the graph with nodes reordered by ``perm`` (new <- old)."""
        perm = np.asarray(perm)
        inv = perm  # rows/cols gathered by perm
        return Graph(
            adjacency=self.adjacency[np.ix_(inv, inv)],
            edge_labels=self.edge_labels[np.ix_(inv, inv)],
            vertex_labels=self.vertex_labels[inv],
            start_prob=self.start_prob[inv],
            stop_prob=self.stop_prob[inv],
        )

    def degrees(self) -> np.ndarray:
        """Paper's degree definition: d_i = sum_j A_ij + q_i."""
        return self.adjacency.sum(axis=1) + self.stop_prob


class GraphBatch(NamedTuple):
    """Fixed-shape padded batch of graphs (a jax pytree).

    Shapes (B = batch, N = padded node count):
      adjacency    [B, N, N]   zero-padded
      edge_labels  [B, N, N]   zero-padded
      vertex_labels[B, N]      zero-padded (mask decides validity)
      start_prob   [B, N]      zero-padded
      stop_prob    [B, N]      zero-padded
      degrees      [B, N]      ONE-padded (keeps the padded diagonal SPD)
      node_mask    [B, N]      1.0 for real nodes
      n_nodes      [B]         int32 true node counts
    """

    adjacency: jnp.ndarray
    edge_labels: jnp.ndarray
    vertex_labels: jnp.ndarray
    start_prob: jnp.ndarray
    stop_prob: jnp.ndarray
    degrees: jnp.ndarray
    node_mask: jnp.ndarray
    n_nodes: jnp.ndarray

    @property
    def batch_size(self) -> int:
        return self.adjacency.shape[0]

    @property
    def padded_nodes(self) -> int:
        return self.adjacency.shape[1]


def pad_graphs(graphs: Sequence[Graph], pad_to: int | None = None,
               multiple_of: int = 8) -> dict[str, np.ndarray]:
    """Pad a list of graphs to a common node count (numpy, host side)."""
    max_n = max(g.n_nodes for g in graphs)
    if pad_to is None:
        pad_to = -(-max_n // multiple_of) * multiple_of
    if pad_to < max_n:
        raise ValueError(f"pad_to={pad_to} < largest graph ({max_n})")
    B, N = len(graphs), pad_to
    out = {
        "adjacency": np.zeros((B, N, N), np.float32),
        "edge_labels": np.zeros((B, N, N), np.float32),
        "vertex_labels": np.zeros((B, N), np.float32),
        "start_prob": np.zeros((B, N), np.float32),
        "stop_prob": np.zeros((B, N), np.float32),
        "degrees": np.ones((B, N), np.float32),
        "node_mask": np.zeros((B, N), np.float32),
        "n_nodes": np.zeros((B,), np.int32),
    }
    for b, g in enumerate(graphs):
        n = g.n_nodes
        out["adjacency"][b, :n, :n] = g.adjacency
        out["edge_labels"][b, :n, :n] = g.edge_labels
        out["vertex_labels"][b, :n] = g.vertex_labels
        out["start_prob"][b, :n] = g.start_prob
        out["stop_prob"][b, :n] = g.stop_prob
        out["degrees"][b, :n] = g.degrees()
        out["node_mask"][b, :n] = 1.0
        out["n_nodes"][b] = n
    return out


def batch_from_graphs(graphs: Sequence[Graph], pad_to: int | None = None,
                      multiple_of: int = 8) -> GraphBatch:
    arrs = pad_graphs(graphs, pad_to=pad_to, multiple_of=multiple_of)
    return GraphBatch(**{k: jnp.asarray(v) for k, v in arrs.items()})
