"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and keys/values are produced through low-rank latent projections;
the KV cache stores only the compressed latent (kv_lora_rank) plus the
shared rope key — the architecture's memory saving. Decode here is the
"naive" (un-absorbed) form: cached latents are up-projected each step.
The absorbed-matmul variant is a §Perf hillclimb (launch/dryrun --variant
mla_absorbed) — see EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.kernels.ops import attention
from .layers import rms_norm, rope

__all__ = ["MLAParams", "MLACache", "mla_init", "mla_layer"]


class MLAParams(NamedTuple):
    w_dq: jnp.ndarray       # [d, q_lora]
    q_norm: jnp.ndarray     # [q_lora]
    w_uq: jnp.ndarray       # [q_lora, H*(nope+rope)]
    w_dkv: jnp.ndarray      # [d, kv_lora + rope]
    kv_norm: jnp.ndarray    # [kv_lora]
    w_uk: jnp.ndarray       # [kv_lora, H*nope]
    w_uv: jnp.ndarray       # [kv_lora, H*v_dim]
    wo: jnp.ndarray         # [H*v_dim, d]


class MLACache(NamedTuple):
    ckv: jnp.ndarray        # [B, S_max, kv_lora]   compressed latents
    krope: jnp.ndarray      # [B, S_max, rope_dim]  shared rope key


def mla_init(key, d: int, n_heads: int, cfg: MLAConfig, dtype) -> MLAParams:
    ks = jax.random.split(key, 6)
    qh = cfg.qk_nope_dim + cfg.qk_rope_dim

    def init(k, shape):
        return (jax.random.normal(k, shape) * shape[0] ** -0.5).astype(dtype)

    return MLAParams(
        w_dq=init(ks[0], (d, cfg.q_lora_rank)),
        q_norm=jnp.zeros((cfg.q_lora_rank,), dtype),
        w_uq=init(ks[1], (cfg.q_lora_rank, n_heads * qh)),
        w_dkv=init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim)),
        kv_norm=jnp.zeros((cfg.kv_lora_rank,), dtype),
        w_uk=init(ks[3], (cfg.kv_lora_rank, n_heads * cfg.qk_nope_dim)),
        w_uv=init(ks[4], (cfg.kv_lora_rank, n_heads * cfg.v_head_dim)),
        wo=init(ks[5], (n_heads * cfg.v_head_dim, d)),
    )


def mla_layer(p: MLAParams, x, cfg: MLAConfig, *, n_heads: int, positions,
              rope_theta: float, impl: str = "reference",
              cache: MLACache | None = None, cache_pos=None,
              rms_eps: float = 1e-6):
    """Returns (out [B,S,d], new_cache | None)."""
    B, S, _ = x.shape
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p.w_dq), p.q_norm, rms_eps)
    q = jnp.einsum("bsr,rh->bsh", cq, p.w_uq).reshape(
        B, S, n_heads, nope + rdim).transpose(0, 2, 1, 3)  # [B,H,S,nope+r]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, p.w_dkv)
    ckv = rms_norm(dkv[..., :cfg.kv_lora_rank], p.kv_norm, rms_eps)
    krope_new = rope(dkv[..., None, :, cfg.kv_lora_rank:].swapaxes(1, 2)
                     .reshape(B, 1, S, rdim), positions, rope_theta)
    krope_new = krope_new[:, 0]                             # [B, S, rdim]

    new_cache = None
    if cache is not None:
        start = cache_pos if cache_pos is not None else 0
        cckv = jax.lax.dynamic_update_slice(
            cache.ckv, ckv.astype(cache.ckv.dtype), (0, start, 0))
        ckr = jax.lax.dynamic_update_slice(
            cache.krope, krope_new.astype(cache.krope.dtype), (0, start, 0))
        new_cache = MLACache(cckv, ckr)
        ckv_all, krope_all = cckv.astype(x.dtype), ckr.astype(x.dtype)
    else:
        ckv_all, krope_all = ckv, krope_new

    k_nope = jnp.einsum("bsr,rh->bsh", ckv_all, p.w_uk).reshape(
        B, -1, n_heads, nope).transpose(0, 2, 1, 3)         # [B,H,Sk,nope]
    v = jnp.einsum("bsr,rh->bsh", ckv_all, p.w_uv).reshape(
        B, -1, n_heads, vdim).transpose(0, 2, 1, 3)         # [B,H,Sk,vdim]
    k_rope = jnp.broadcast_to(krope_all[:, None],
                              (B, n_heads) + krope_all.shape[1:])

    scale = (nope + rdim) ** -0.5
    if cache is not None and S == 1:
        start = cache_pos
        logits = (jnp.einsum("bhqd,bhkd->bhqk", q_nope, k_nope) +
                  jnp.einsum("bhqd,bhkd->bhqk", q_rope, k_rope)) * scale
        kpos = jnp.arange(k_nope.shape[2])
        mask = kpos[None, None, None, :] <= start
        logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bhkd->bhqd", w, v)
    else:
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        kfull = jnp.concatenate([k_nope, k_rope], axis=-1)
        # pad v to qk head size for the shared attention kernel, then slice
        out = attention(qfull, kfull,
                        jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                    (0, nope + rdim - vdim))),
                        impl=impl, causal=True, scale=scale)[..., :vdim]
    out = out.transpose(0, 2, 1, 3).reshape(B, S, n_heads * vdim)
    return jnp.einsum("bsh,hd->bsd", out, p.wo), new_cache
