"""Mamba-2 block via the SSD (state-space duality) chunked algorithm
(arXiv:2405.21060) plus O(1)-state decode.

Train/prefill: the sequence is cut into chunks of length Q; within-chunk
terms use the dual quadratic (attention-like) form with the 1-semiseparable
decay mask; chunk states are passed through a jax.lax.scan recurrence
(linear in sequence length). Decode: constant-size state update — the
reason mamba2/jamba are the only two archs that run the long_500k cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from .layers import rms_norm

__all__ = ["MambaParams", "MambaCache", "mamba_init", "mamba_layer",
           "mamba_decode"]


class MambaParams(NamedTuple):
    in_proj: jnp.ndarray    # [d, 2*d_in + 2*state + H]
    conv_w: jnp.ndarray     # [width, conv_dim]
    conv_b: jnp.ndarray     # [conv_dim]
    dt_bias: jnp.ndarray    # [H]
    A_log: jnp.ndarray      # [H]
    D: jnp.ndarray          # [H]
    norm_w: jnp.ndarray     # [d_in]
    out_proj: jnp.ndarray   # [d_in, d]


class MambaCache(NamedTuple):
    conv: jnp.ndarray       # [B, width-1, conv_dim]
    state: jnp.ndarray      # [B, H, P, N]


def _dims(d: int, cfg: SSMConfig):
    d_in = cfg.expand * d
    n_heads = d_in // cfg.head_dim
    conv_dim = d_in + 2 * cfg.d_state
    return d_in, n_heads, conv_dim


def mamba_init(key, d: int, cfg: SSMConfig, dtype) -> MambaParams:
    d_in, H, conv_dim = _dims(d, cfg)
    ks = jax.random.split(key, 4)
    return MambaParams(
        in_proj=(jax.random.normal(ks[0], (d, 2 * d_in + 2 * cfg.d_state + H))
                 * d ** -0.5).astype(dtype),
        conv_w=(jax.random.normal(ks[1], (cfg.conv_width, conv_dim))
                * cfg.conv_width ** -0.5).astype(dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        dt_bias=jnp.zeros((H,), jnp.float32),
        A_log=jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        D=jnp.ones((H,), jnp.float32),
        norm_w=jnp.zeros((d_in,), dtype),
        out_proj=(jax.random.normal(ks[3], (d_in, d))
                  * d_in ** -0.5).astype(dtype),
    )


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv. x: [B, S, C]; w: [W, C]. Returns (y, tail)."""
    W = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)           # [B, S+W-1, C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W)) + b
    tail = xp[:, -(W - 1):, :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), tail


def _ssd_chunked(xh, a, Bm, Cm, chunk: int):
    """SSD scan. xh: [B,S,H,P] (already dt-scaled); a: [B,S,H] log-decay;
    Bm, Cm: [B,S,N]. Returns y [B,S,H,P] and final state [B,H,P,N]."""
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S) if S % chunk else chunk
    if S % Q:
        # pad to a chunk multiple with inert steps: x=0, B=0 contribute
        # nothing; a=0 (decay 1) keeps the final state exact
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_orig = S
    S = xh.shape[1]
    nc = S // Q
    xh = xh.reshape(B, nc, Q, H, Pd)
    a = a.reshape(B, nc, Q, H)
    Bm = Bm.reshape(B, nc, Q, N)
    Cm = Cm.reshape(B, nc, Q, N)

    cum = jnp.cumsum(a, axis=2)                      # [B,nc,Q,H]
    # intra-chunk (dual quadratic form): L[i,j] = exp(cum_i - cum_j), i>=j
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask the EXPONENT (not the result): exp on the i<j branch overflows
    # and its inf would leak NaN through where()'s gradient
    rel = jnp.where(tri[None, None, :, :, None], rel, -jnp.inf)
    L = jnp.exp(rel)
    scores = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)   # [B,nc,Q,Q]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores,
                         L.astype(xh.dtype), xh)

    # per-chunk input state contribution
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,Q,H]
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                     Bm, decay_to_end.astype(xh.dtype), xh)

    chunk_decay = jnp.exp(cum[:, :, -1, :])          # [B,nc,H]

    def scan_fn(carry, inp):
        s_c, dec = inp                               # [B,H,P,N], [B,H]
        new = carry * dec[:, :, None, None].astype(carry.dtype) + s_c
        return new, carry                            # emit state BEFORE chunk

    init = jnp.zeros((B, H, Pd, N), xh.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cm, jnp.exp(cum).astype(xh.dtype), prev_states)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)[:, :S_orig]
    return y, final


def _project(p: MambaParams, x, cfg: SSMConfig):
    d_in, H, conv_dim = _dims(x.shape[-1], cfg)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p.in_proj)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + conv_dim]
    dt_raw = zxbcdt[..., d_in + conv_dim:]
    return z, xbc, dt_raw, (d_in, H, conv_dim)


def mamba_layer(p: MambaParams, x, cfg: SSMConfig, *, cache=None):
    """Full-sequence SSD. Returns (out [B,S,d], MambaCache for decode)."""
    Bsz, S, d = x.shape
    z, xbc, dt_raw, (d_in, H, conv_dim) = _project(p, x, cfg)
    xbc, conv_tail = _causal_conv(xbc, p.conv_w, p.conv_b)
    xs = xbc[..., :d_in]
    Bm = xbc[..., d_in:d_in + cfg.d_state]
    Cm = xbc[..., d_in + cfg.d_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)  # [B,S,H]
    A = -jnp.exp(p.A_log)                                          # [H]
    a = dt * A[None, None, :]                                      # log decay
    xh = xs.reshape(Bsz, S, H, cfg.head_dim)
    xh_dt = xh * dt[..., None].astype(xh.dtype)
    y, final_state = _ssd_chunked(xh_dt, a, Bm, Cm, cfg.chunk)
    y = y + p.D[None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(Bsz, S, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p.norm_w)
    out = jnp.einsum("bsk,kd->bsd", y, p.out_proj)
    new_cache = MambaCache(conv=conv_tail, state=final_state) \
        if cache is not None else None
    return out, new_cache


def mamba_decode(p: MambaParams, x, cfg: SSMConfig, cache: MambaCache):
    """One-token step. x: [B, 1, d]. Returns (out [B,1,d], new cache)."""
    Bsz, S, d = x.shape
    assert S == 1
    z, xbc, dt_raw, (d_in, H, conv_dim) = _project(p, x, cfg)
    xbc, conv_tail = _causal_conv(xbc, p.conv_w, p.conv_b, cache=cache.conv)
    xs = xbc[..., :d_in]
    Bm = xbc[..., d_in:d_in + cfg.d_state][:, 0]     # [B, N]
    Cm = xbc[..., d_in + cfg.d_state:][:, 0]         # [B, N]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p.dt_bias)[:, 0]
    A = -jnp.exp(p.A_log)
    decay = jnp.exp(dt * A[None, :])                 # [B, H]
    xh = xs.reshape(Bsz, H, cfg.head_dim)            # [B, H, P]
    xh_dt = xh * dt[..., None].astype(xh.dtype)
    state = cache.state * decay[:, :, None, None].astype(cache.state.dtype)
    state = state + jnp.einsum("bn,bhp->bhpn", Bm, xh_dt)
    y = jnp.einsum("bn,bhpn->bhp", Cm, state)        # [B, H, P]
    y = y + p.D[None, :, None].astype(y.dtype) * xh
    y = y.reshape(Bsz, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p.norm_w)
    out = jnp.einsum("bsk,kd->bsd", y, p.out_proj)
    return out, MambaCache(conv=conv_tail, state=state)
