"""Shared transformer building blocks (functional; params = nested dicts).

All layers support two modes:
  * full-sequence (train / prefill): x [B, S, d]; returns cache if asked;
  * decode: x [B, 1, d] + cache (k/v [B, S_max, kv, hd]) + position index.

Shape convention for attention internals: [B, H, S, D] (head-major) to
match kernels/flash_attention.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.ops import attention

__all__ = ["rms_norm", "rope", "swiglu", "AttnParams", "attn_init",
           "attention_layer", "KVCache", "mlp_init", "embed_init"]


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + weight)).astype(dtype)


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def rope(x, positions, theta: float = 10_000.0):
    """x: [B, H, S, D]; positions: [B, S] (or [S])."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # B,1,S,D/2
    cos = jnp.concatenate([jnp.cos(ang)] * 2, axis=-1)
    sin = jnp.concatenate([jnp.sin(ang)] * 2, axis=-1)
    return (x * cos + _rotate_half(x) * sin).astype(x.dtype)


# -- initializers -------------------------------------------------------------

def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * d ** -0.5).astype(dtype)


def mlp_init(key, d: int, ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d, ff), dtype),
        "w_up": _dense_init(k2, (d, ff), dtype),
        "w_down": _dense_init(k3, (ff, d), dtype),
    }


def swiglu(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


# -- attention ----------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray   # [B, S_max, KV, HD]
    v: jnp.ndarray   # [B, S_max, KV, HD]


class AttnParams(NamedTuple):
    wq: jnp.ndarray       # [d, H*HD]
    wk: jnp.ndarray       # [d, KV*HD]
    wv: jnp.ndarray       # [d, KV*HD]
    wo: jnp.ndarray       # [H*HD, d]
    q_norm: jnp.ndarray | None
    k_norm: jnp.ndarray | None


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, dtype,
              qk_norm: bool = False) -> AttnParams:
    ks = jax.random.split(key, 4)
    return AttnParams(
        wq=_dense_init(ks[0], (d, n_heads * head_dim), dtype),
        wk=_dense_init(ks[1], (d, n_kv * head_dim), dtype),
        wv=_dense_init(ks[2], (d, n_kv * head_dim), dtype),
        wo=_dense_init(ks[3], (n_heads * head_dim, d), dtype),
        q_norm=jnp.zeros((head_dim,), dtype) if qk_norm else None,
        k_norm=jnp.zeros((head_dim,), dtype) if qk_norm else None,
    )


def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd).transpose(0, 2, 1, 3)   # [B, n, S, hd]


def attention_layer(p: AttnParams, x, *, n_heads: int, n_kv: int,
                    head_dim: int, positions, rope_theta: float | None,
                    causal: bool = True, window: int | None = None,
                    cache: KVCache | None = None,
                    cache_pos=None,
                    impl: str = "reference",
                    rms_eps: float = 1e-6,
                    kv_override=None):
    """GQA attention. Returns (out [B,S,d], new_cache | None).

    * train/prefill: cache=None or a zeroed cache to fill (prefill).
    * decode: x is [B,1,d]; cache holds S_max history; cache_pos the write
      index (scalar int32).
    * cross-attention: pass kv_override = (k_in [B,Skv,d_src] already
      projected? no: raw source states) — here kv_override is the source
      sequence [B, S_kv, d]; keys/values are projected from it and cache
      semantics don't apply.
    """
    B, S, _ = x.shape
    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p.wq), n_heads, head_dim)
    kv_src = kv_override if kv_override is not None else x
    k = _split_heads(jnp.einsum("bsd,dh->bsh", kv_src, p.wk), n_kv, head_dim)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", kv_src, p.wv), n_kv, head_dim)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, rms_eps)
        k = rms_norm(k, p.k_norm, rms_eps)
    if rope_theta is not None and kv_override is None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)

    new_cache = None
    if cache is not None and kv_override is None:
        # write current k/v into the cache at cache_pos
        k_bsnh = k.transpose(0, 2, 1, 3)      # [B, S, KV, HD]
        v_bsnh = v.transpose(0, 2, 1, 3)
        start = cache_pos if cache_pos is not None else 0
        ck = jax.lax.dynamic_update_slice(
            cache.k, k_bsnh.astype(cache.k.dtype), (0, start, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache.v, v_bsnh.astype(cache.v.dtype), (0, start, 0, 0))
        new_cache = KVCache(ck, cv)
        if S == 1:
            # decode: attend over the whole cache, GQA-native (no repeat /
            # transpose copies of the cache — those dominate HBM traffic)
            rep = n_heads // n_kv
            q_r = q[:, :, 0, :].reshape(B, n_kv, rep, head_dim)
            logits = jnp.einsum("bgrd,bsgd->bgrs", q_r,
                                ck.astype(q.dtype)) * head_dim ** -0.5
            kpos = jnp.arange(ck.shape[1])
            mask = kpos[None, None, None, :] <= start
            if window is not None:
                mask &= kpos[None, None, None, :] > start - window
            logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
            w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
            out = jnp.einsum("bgrs,bsgd->bgrd", w, cv.astype(q.dtype))
            out = out.reshape(B, 1, n_heads * head_dim)
            return jnp.einsum("bsh,hd->bsd", out, p.wo), new_cache

    out = attention(q, k, v, impl=impl,
                    causal=causal and kv_override is None, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return jnp.einsum("bsh,hd->bsd", out, p.wo), new_cache
