"""Unified model assembly for the architecture zoo.

Every architecture is described as a list of SEGMENTS; a segment is
``(n_super, pattern)`` where ``pattern`` is a list of (mixer, mlp) layer
kinds forming one "superblock". The segment runs as ``jax.lax.scan`` over
``n_super`` stacked superblocks (remat-wrapped in training), which keeps
the HLO size O(pattern) instead of O(n_layers) — essential for compiling
the 100-layer configs on the 512-device dry-run mesh.

  mixer: attn | attn_local | attn_global | enc_attn | cross | dec
         | mla | mamba
  mlp:   dense | moe | none

Examples:
  qwen3-14b        [(40, [(attn, dense)])]
  gemma3-12b       [(8,  [(attn_local, dense)]*5 + [(attn_global, dense)])]
  deepseek-v3      [(3,  [(mla, dense)]), (58, [(mla, moe)])]
  jamba-1.5        [(9,  [(attn, dense), (mamba, moe), (mamba, dense), ...])]
  whisper (dec)    [(32, [(dec, dense)])]   # dec = self + cross + mlp
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import (AttnParams, KVCache, attention_layer, attn_init,
                     embed_init, mlp_init, rms_norm, swiglu)
from .mamba2 import (MambaCache, mamba_decode, mamba_init, mamba_layer)
from .mla import MLACache, mla_init, mla_layer
from .moe import moe_init, moe_layer

__all__ = ["Model", "init_params", "abstract_params", "forward",
           "segments_of", "init_cache", "abstract_cache"]

Segment = tuple[int, list[tuple[str, str]]]


# ---------------------------------------------------------------------------
# architecture plan
# ---------------------------------------------------------------------------

def segments_of(cfg: ModelConfig, part: str = "decoder") -> list[Segment]:
    if part == "encoder":
        assert cfg.encoder_layers
        return [(cfg.encoder_layers, [("enc_attn", "dense")])]
    if cfg.family == "audio":
        return [(cfg.n_layers, [("dec", "dense")])]
    if cfg.family == "ssm":
        return [(cfg.n_layers, [("mamba", "none")])]
    if cfg.attn_every:                                   # jamba-style hybrid
        pat = []
        for j in range(cfg.attn_every):
            mixer = "attn" if j == 0 else "mamba"
            mlp = "moe" if (cfg.moe and
                            j % cfg.moe.moe_every == cfg.moe.moe_every - 1) \
                else "dense"
            pat.append((mixer, mlp))
        assert cfg.n_layers % cfg.attn_every == 0
        return [(cfg.n_layers // cfg.attn_every, pat)]
    if cfg.local_global_ratio:                           # gemma3
        r = cfg.local_global_ratio
        pat = [("attn_local", "dense")] * r + [("attn_global", "dense")]
        assert cfg.n_layers % (r + 1) == 0
        return [(cfg.n_layers // (r + 1), pat)]
    if cfg.cross_attn_every:                             # llama-vision
        c = cfg.cross_attn_every
        pat = [("attn", "dense")] * (c - 1) + [("cross", "dense")]
        assert cfg.n_layers % c == 0
        return [(cfg.n_layers // c, pat)]
    mlp = "moe" if cfg.moe else "dense"
    segs: list[Segment] = []
    if cfg.n_dense_layers:                               # deepseek-v3
        mixer = "mla" if cfg.mla else "attn"
        segs.append((cfg.n_dense_layers, [(mixer, "dense")]))
        segs.append((cfg.n_layers - cfg.n_dense_layers, [(mixer, mlp)]))
        return segs
    mixer = "mla" if cfg.mla else "attn"
    return [(cfg.n_layers, [(mixer, mlp)])]


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, mixer: str, mlp: str) -> dict:
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": jnp.zeros((d,), dt)}
    if mixer in ("attn", "attn_local", "attn_global", "enc_attn"):
        p["attn"] = attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim, dt, qk_norm=cfg.qk_norm)
    elif mixer == "cross":
        p["cross"] = attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dt)
        p["cross_gate"] = jnp.zeros((), dt)
    elif mixer == "dec":
        p["attn"] = attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim, dt)
        p["cross"] = attn_init(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dt)
        p["norm_cross"] = jnp.zeros((d,), dt)
    elif mixer == "mla":
        p["mla"] = mla_init(ks[0], d, cfg.n_heads, cfg.mla, dt)
    elif mixer == "mamba":
        p["mamba"] = mamba_init(ks[0], d, cfg.ssm, dt)
    else:
        raise ValueError(mixer)
    if mlp != "none":
        p["norm2"] = jnp.zeros((d,), dt)
        if mlp == "dense":
            p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, dt)
        else:
            p["moe"] = moe_init(ks[2], d, cfg.moe, dt)
    return p


def _segment_init(key, cfg: ModelConfig, seg: Segment) -> dict:
    n_super, pattern = seg
    out = {}
    for j, (mixer, mlp) in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), n_super)
        stacked = jax.vmap(
            lambda k: _layer_init(k, cfg, mixer, mlp))(keys)
        out[f"l{j}"] = stacked
    return out


def init_params(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model, dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[1], cfg.vocab_padded,
                                       cfg.d_model, dt)
    for i, seg in enumerate(segments_of(cfg)):
        params[f"seg{i}"] = _segment_init(jax.random.fold_in(ks[2], i),
                                          cfg, seg)
    if cfg.encoder_layers:
        for i, seg in enumerate(segments_of(cfg, "encoder")):
            params[f"enc_seg{i}"] = _segment_init(
                jax.random.fold_in(ks[3], i), cfg, seg)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.mtp_heads:
        params["mtp"] = {
            "proj": (jax.random.normal(ks[4], (2 * cfg.d_model, cfg.d_model))
                     * (2 * cfg.d_model) ** -0.5).astype(dt),
            "norm": jnp.zeros((cfg.d_model,), dt),
            "layer": _layer_init(ks[5], cfg, "mla" if cfg.mla else "attn",
                                 "moe" if cfg.moe else "dense"),
        }
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# caches (for decode)
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, mixer: str, batch: int, s_max: int,
                 dtype) -> Any:
    if mixer in ("attn", "attn_local", "attn_global"):
        shape = (batch, s_max, cfg.n_kv_heads, cfg.head_dim)
        return {"kv": KVCache(jnp.zeros(shape, dtype),
                              jnp.zeros(shape, dtype))}
    if mixer == "dec":
        shape = (batch, s_max, cfg.n_kv_heads, cfg.head_dim)
        cshape = (batch, cfg.vision_tokens or 1500, cfg.n_kv_heads,
                  cfg.head_dim)
        return {"kv": KVCache(jnp.zeros(shape, dtype),
                              jnp.zeros(shape, dtype)),
                "cross_kv": KVCache(jnp.zeros(cshape, dtype),
                                    jnp.zeros(cshape, dtype))}
    if mixer == "cross":
        cshape = (batch, cfg.vision_tokens, cfg.n_kv_heads, cfg.head_dim)
        return {"cross_kv": KVCache(jnp.zeros(cshape, dtype),
                                    jnp.zeros(cshape, dtype))}
    if mixer == "mla":
        c = cfg.mla
        return {"mla": MLACache(
            jnp.zeros((batch, s_max, c.kv_lora_rank), dtype),
            jnp.zeros((batch, s_max, c.qk_rope_dim), dtype))}
    if mixer == "mamba":
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        h = d_in // s.head_dim
        conv_dim = d_in + 2 * s.d_state
        return {"mamba": MambaCache(
            jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
            jnp.zeros((batch, h, s.head_dim, s.d_state), dtype))}
    raise ValueError(mixer)


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               dtype=None) -> dict:
    dtype = jnp.dtype(dtype or cfg.dtype)
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    for i, (n_super, pattern) in enumerate(segments_of(cfg)):
        seg: dict[str, Any] = {}
        for j, (mixer, _) in enumerate(pattern):
            one = _layer_cache(cfg, mixer, batch, s_max, dtype)
            seg[f"l{j}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_super,) + x.shape),
                one)
        cache[f"seg{i}"] = seg
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=None):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, s_max, dtype))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, lp: dict, x, mixer: str, mlp: str, *,
                 positions, memory, lcache, cache_pos, decode: bool):
    """One (mixer, mlp) layer with pre-norms and residuals.
    Returns (x, new_lcache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["norm1"], cfg.rms_eps)
    new_lcache = dict(lcache) if lcache is not None else None

    def kv(name):
        return lcache[name] if lcache is not None else None

    if mixer in ("attn", "attn_local", "attn_global"):
        window = cfg.sliding_window if mixer == "attn_local" else None
        out, nc = attention_layer(
            lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, positions=positions,
            rope_theta=cfg.rope_theta, causal=True, window=window,
            cache=kv("kv"), cache_pos=cache_pos,
            impl=cfg.attention_impl, rms_eps=cfg.rms_eps)
        if new_lcache is not None:
            new_lcache["kv"] = nc
        x = x + out
    elif mixer == "enc_attn":
        out, _ = attention_layer(
            lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, positions=positions, rope_theta=None,
            causal=False, impl=cfg.attention_impl, rms_eps=cfg.rms_eps)
        x = x + out
    elif mixer == "cross":
        if decode:
            out = _cross_from_cache(cfg, lp["cross"], h, lcache["cross_kv"])
            nc = lcache["cross_kv"]
        else:
            out, nc = _cross_full(cfg, lp["cross"], h, memory,
                                  want_cache=lcache is not None)
        if new_lcache is not None:
            new_lcache["cross_kv"] = nc
        x = x + jnp.tanh(lp["cross_gate"].astype(jnp.float32)).astype(
            x.dtype) * out
    elif mixer == "dec":
        out, nc = attention_layer(
            lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, positions=positions,
            rope_theta=cfg.rope_theta, causal=True,
            cache=kv("kv"), cache_pos=cache_pos,
            impl=cfg.attention_impl, rms_eps=cfg.rms_eps)
        if new_lcache is not None:
            new_lcache["kv"] = nc
        x = x + out
        h2 = rms_norm(x, lp["norm_cross"], cfg.rms_eps)
        if decode:
            out = _cross_from_cache(cfg, lp["cross"], h2, lcache["cross_kv"])
        else:
            out, nc2 = _cross_full(cfg, lp["cross"], h2, memory,
                                   want_cache=lcache is not None)
            if new_lcache is not None:
                new_lcache["cross_kv"] = nc2
        x = x + out
    elif mixer == "mla":
        out, nc = mla_layer(
            lp["mla"], h, cfg.mla, n_heads=cfg.n_heads, positions=positions,
            rope_theta=cfg.rope_theta, impl=cfg.attention_impl,
            cache=lcache["mla"] if lcache is not None else None,
            cache_pos=cache_pos, rms_eps=cfg.rms_eps)
        if new_lcache is not None:
            new_lcache["mla"] = nc
        x = x + out
    elif mixer == "mamba":
        if decode:
            out, nc = mamba_decode(lp["mamba"], h, cfg.ssm,
                                   cache=lcache["mamba"])
        else:
            out, nc = mamba_layer(lp["mamba"], h, cfg.ssm,
                                  cache=lcache["mamba"]
                                  if lcache is not None else None)
        if new_lcache is not None:
            new_lcache["mamba"] = nc
        x = x + out
    else:
        raise ValueError(mixer)

    if mlp == "dense":
        x = x + swiglu(lp["mlp"], rms_norm(x, lp["norm2"], cfg.rms_eps))
    elif mlp == "moe":
        out, a = moe_layer(lp["moe"], rms_norm(x, lp["norm2"], cfg.rms_eps),
                           cfg.moe)
        x = x + out
        aux = aux + a
    return x, new_lcache, aux


def _cross_full(cfg: ModelConfig, p: AttnParams, h, memory, want_cache):
    """Cross-attention over memory [B, V, d]; optionally returns the
    projected cross-KV (built once at prefill, read-only at decode)."""
    B = h.shape[0]
    out, _ = attention_layer(
        p, h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.head_dim, positions=None, rope_theta=None,
        causal=False, impl=cfg.attention_impl, kv_override=memory)
    nc = None
    if want_cache:
        k = jnp.einsum("bvd,dh->bvh", memory, p.wk).reshape(
            B, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
        v = jnp.einsum("bvd,dh->bvh", memory, p.wv).reshape(
            B, memory.shape[1], cfg.n_kv_heads, cfg.head_dim)
        nc = KVCache(k, v)
    return out, nc


def _cross_from_cache(cfg: ModelConfig, p: AttnParams, h, ckv: KVCache):
    """Decode-time cross-attention against pre-projected memory KV
    (GQA-native einsums; no repeat/transpose copies of the memory)."""
    B, S, _ = h.shape
    rep = cfg.n_heads // cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", h, p.wq).reshape(
        B, S, cfg.n_kv_heads, rep, cfg.head_dim)
    logits = jnp.einsum("bsgrd,bvgd->bsgrv", q, ckv.k.astype(q.dtype))
    logits = logits * cfg.head_dim ** -0.5
    w = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(q.dtype)
    out = jnp.einsum("bsgrv,bvgd->bsgrd", w, ckv.v.astype(q.dtype))
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", out, p.wo)


def _run_segments(cfg: ModelConfig, params, x, *, prefix: str, part: str,
                  positions, memory, cache, cache_pos, decode: bool,
                  training: bool):
    """Apply all segments; returns (x, new_cache, aux_total)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}
    for i, (n_super, pattern) in enumerate(segments_of(cfg, part)):
        seg_params = params[f"{prefix}seg{i}"]
        seg_cache = cache.get(f"seg{i}") if cache is not None else None

        def superblock(carry, xs):
            x, aux = carry
            sp, sc = xs
            nsc = {} if sc is not None else None
            for j, (mixer, mlp) in enumerate(pattern):
                lc = sc[f"l{j}"] if sc is not None else None
                x, nlc, a = _apply_layer(
                    cfg, sp[f"l{j}"], x, mixer, mlp, positions=positions,
                    memory=memory, lcache=lc, cache_pos=cache_pos,
                    decode=decode)
                if nsc is not None:
                    nsc[f"l{j}"] = nlc
                aux = aux + a
            return (x, aux), nsc

        body = superblock
        if training and cfg.remat != "none":
            policy = None
            if cfg.remat == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            body = jax.checkpoint(superblock, policy=policy,
                                  prevent_cse=False)

        (x, aux_total), seg_cache_out = jax.lax.scan(
            body, (x, aux_total), (seg_params, seg_cache))
        if cache is not None:
            new_cache[f"seg{i}"] = seg_cache_out
    return x, (new_cache if cache is not None else None), aux_total


def _sinusoidal(s: int, d: int, dtype):
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None, :].astype(jnp.float32)
    ang = pos / (10_000.0 ** (dim / d))
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)


def encode(cfg: ModelConfig, params, frames):
    """Whisper-style encoder over stub frame embeddings [B, S_enc, d]."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model, frames.dtype)
    pos = jnp.arange(frames.shape[1])
    x, _, _ = _run_segments(cfg, params, x, prefix="enc_", part="encoder",
                            positions=pos, memory=None, cache=None,
                            cache_pos=None, decode=False, training=False)
    return rms_norm(x, params["enc_norm"], cfg.rms_eps)


def forward(cfg: ModelConfig, params, batch: dict, *, training: bool = False,
            cache: dict | None = None, return_hidden: bool = False):
    """Full-sequence forward (train / prefill).

    batch keys: "tokens" [B, S] int32; optional "vision" [B, V, d] (vlm),
    "audio_frames" [B, S_enc, d] (audio). Returns (logits, new_cache, aux)
    or (..., hidden) with return_hidden.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    memory = None
    if cfg.family == "vlm":
        memory = batch["vision"]
    elif cfg.family == "audio":
        memory = encode(cfg, params, batch["audio_frames"])
    positions = jnp.arange(S)
    cache_pos = 0 if cache is not None else None
    x, new_cache, aux = _run_segments(
        cfg, params, x, prefix="", part="decoder", positions=positions,
        memory=memory, cache=cache, cache_pos=cache_pos, decode=False,
        training=training)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    if new_cache is not None:
        new_cache["pos"] = jnp.asarray(S, jnp.int32)
    if return_hidden:
        return logits, new_cache, aux, x
    return logits, new_cache, aux


def decode_step(cfg: ModelConfig, params, cache: dict, token):
    """One decode step. token: [B, 1] int32. Returns (logits, new_cache)."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], token, axis=0)
    positions = jnp.full((token.shape[0], 1), pos, jnp.int32)
    x, new_cache, _ = _run_segments(
        cfg, params, x, prefix="", part="decoder", positions=positions,
        memory=None, cache=cache, cache_pos=pos, decode=True,
        training=False)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def mtp_logits(cfg: ModelConfig, params, hidden, next_embed):
    """DeepSeek multi-token-prediction head: predict token t+2 from the
    final hidden state combined with the embedding of token t+1."""
    h = jnp.concatenate([hidden, next_embed], axis=-1)
    h = jnp.einsum("bsd,dk->bsk", h, params["mtp"]["proj"])
    h = rms_norm(h, params["mtp"]["norm"], cfg.rms_eps)
    h, _, _ = _apply_layer(
        cfg, params["mtp"]["layer"], h, "mla" if cfg.mla else "attn",
        "moe" if cfg.moe else "dense",
        positions=jnp.arange(h.shape[1]), memory=None, lcache=None,
        cache_pos=None, decode=False)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", h, head)


@dataclasses.dataclass(frozen=True)
class Model:
    """Thin OO wrapper tying a config to the functional API."""
    cfg: ModelConfig

    def init(self, key):
        return init_params(self.cfg, key)

    def apply(self, params, batch, **kw):
        return forward(self.cfg, params, batch, **kw)

    def decode(self, params, cache, token):
        return decode_step(self.cfg, params, cache, token)
