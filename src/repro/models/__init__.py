"""Architecture zoo: composable JAX model definitions for the 10 assigned
architectures (dense / MoE / MLA / SSM / hybrid / VLM / enc-dec audio)."""
from .model import init_params, abstract_params, forward, Model

__all__ = ["init_params", "abstract_params", "forward", "Model"]
