"""Mixture-of-Experts layer — grouped, gather-only, expert-parallel.

Distribution design (the §Perf cell-A/B hillclimbs; see EXPERIMENTS.md):

1. GROUPED ROUTING. Dispatch is grouped by sequence and vmapped over the
   batch axis: top-k, argsort, capacity ranking are all LOCAL to a data
   shard. (A global dispatch lowers to a sort over the sharded token axis:
   the baseline profile was 69x collective-bound because of it.)

2. GATHER-ONLY DATA MOVEMENT. Dispatch (slot <- token) and combine
   (token <- expert row) are both expressed as gathers, and — because the
   two index maps are exact duals — each one's custom_vjp is again a
   gather. No scatter appears in forward OR backward. (XLA expands
   scatters into sort-based code with full-buffer u32 key tensors;
   ~40 GB/layer of HBM traffic in the scatter-based version.)

3. EXPERT PARALLELISM via shard_map. Every model rank recomputes the
   cheap routing for its data shard, evaluates ONLY its E/n_model
   experts, combines locally, and one ACTIVATION-sized psum over "model"
   finishes the layer. Cross-device traffic per layer = |activations|,
   never |dispatch buffers|.

Shared (always-on) experts are plain TP matmuls outside the shard_map.
Capacity per group C = ceil(S * k / E * capacity_factor); overflow tokens
drop (standard capacity semantics; reduced()-config tests run dropless).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig

__all__ = ["MoEParams", "moe_init", "moe_layer"]


class MoEParams(NamedTuple):
    router: jnp.ndarray     # [d, E]
    w_gate: jnp.ndarray     # [E, d, ff]
    w_up: jnp.ndarray       # [E, d, ff]
    w_down: jnp.ndarray     # [E, ff, d]
    shared_gate: jnp.ndarray | None   # [d, n_shared*ff]
    shared_up: jnp.ndarray | None
    shared_down: jnp.ndarray | None


def moe_init(key, d: int, cfg: MoEConfig, dtype) -> MoEParams:
    ks = jax.random.split(key, 7)
    E, ff = cfg.n_experts, cfg.d_expert
    scale_d = d ** -0.5
    scale_f = ff ** -0.5

    def init(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(dtype)

    shared = cfg.n_shared
    return MoEParams(
        router=init(ks[0], (d, E), scale_d).astype(jnp.float32),
        w_gate=init(ks[1], (E, d, ff), scale_d),
        w_up=init(ks[2], (E, d, ff), scale_d),
        w_down=init(ks[3], (E, ff, d), scale_f),
        shared_gate=init(ks[4], (d, shared * ff), scale_d) if shared else None,
        shared_up=init(ks[5], (d, shared * ff), scale_d) if shared else None,
        shared_down=init(ks[6], (shared * ff, d), scale_f) if shared else None,
    )


class Route(NamedTuple):
    """Per-group routing indices (all local to a data shard).
    E_v = the visible expert slice (full E, or a rank's E_loc)."""
    tok_for_slot: jnp.ndarray   # [E_v, C] token feeding each slot
    valid: jnp.ndarray          # [E_v, C]
    gate_for_slot: jnp.ndarray  # [E_v, C] gate of the choice in the slot
    src: jnp.ndarray            # [T, k] flat local expert-output row
    live: jnp.ndarray           # [T, k] choice kept AND visible here
    gate_vals: jnp.ndarray      # [T, k]
    probs: jnp.ndarray          # [T, E] router softmax (aux loss)
    expert_idx: jnp.ndarray     # [T, k]


def _route_group(xt, logits, k: int, E: int, capacity: int) -> Route:
    """Routing bookkeeping for one token group (argsort/cumsum, local)."""
    T, _ = xt.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)      # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_expert = expert_idx.reshape(-1)                 # [T*k]
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = order // k
    sorted_gate = flat_gate[order]
    counts = jnp.bincount(sorted_expert, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[sorted_expert]
    keep = pos < capacity

    slot_idx = starts[:, None] + jnp.arange(capacity)[None, :]   # [E, C]
    valid = jnp.arange(capacity)[None, :] < \
        jnp.minimum(counts, capacity)[:, None]
    clipped = jnp.clip(slot_idx, 0, T * k - 1)
    tok_for_slot = jnp.where(valid, sorted_token[clipped], 0)
    gate_for_slot = jnp.where(valid, sorted_gate[clipped], 0.0)

    inv = jnp.argsort(order)
    pos_flat = pos[inv].reshape(T, k)
    keep_flat = keep[inv].reshape(T, k)
    src = expert_idx * capacity + jnp.minimum(pos_flat, capacity - 1)
    return Route(tok_for_slot, valid, gate_for_slot, src, keep_flat,
                 gate_vals, probs, expert_idx)


def _localize(route: Route, e0, e_loc: int, capacity: int) -> Route:
    """Restrict a full-E Route to expert range [e0, e0+e_loc) and shift
    row indices into the local frame. e0 may be traced (axis_index)."""
    tok = jax.lax.dynamic_slice_in_dim(route.tok_for_slot, e0, e_loc, 0)
    val = jax.lax.dynamic_slice_in_dim(route.valid, e0, e_loc, 0)
    gfs = jax.lax.dynamic_slice_in_dim(route.gate_for_slot, e0, e_loc, 0)
    lo = e0 * capacity
    live = route.live & (route.src >= lo) & \
        (route.src < lo + e_loc * capacity)
    src = jnp.clip(route.src - lo, 0, e_loc * capacity - 1)
    return route._replace(tok_for_slot=tok, valid=val, gate_for_slot=gfs,
                          src=src, live=live)


# -- gather-only dispatch / combine with gather-only custom VJPs -------------

@jax.custom_vjp
def _dispatch(xt, route: Route):
    eb = xt[route.tok_for_slot]                          # [E_v, C, d]
    return eb * route.valid[..., None].astype(xt.dtype)


def _dispatch_fwd(xt, route):
    return _dispatch(xt, route), route


def _dispatch_bwd(route: Route, g_eb):
    ev, C = route.tok_for_slot.shape
    g_flat = (g_eb * route.valid[..., None].astype(g_eb.dtype)
              ).reshape(ev * C, -1)
    rows = g_flat[route.src]                             # [T, k, d] gather
    g_xt = jnp.einsum("tkd,tk->td", rows,
                      route.live.astype(g_eb.dtype))
    return g_xt.astype(g_eb.dtype), None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine(eo_flat, gate_vals, route: Route):
    rows = eo_flat[route.src]                            # [T, k, d] gather
    w = jnp.where(route.live, gate_vals, 0.0).astype(eo_flat.dtype)
    return jnp.einsum("tkd,tk->td", rows, w)


def _combine_fwd(eo_flat, gate_vals, route):
    return _combine(eo_flat, gate_vals, route), (eo_flat, gate_vals, route)


def _combine_bwd(res, g_out):
    eo_flat, gate_vals, route = res
    ev, C = route.tok_for_slot.shape
    g_rows = g_out[route.tok_for_slot.reshape(-1)]       # gather
    g_eo = g_rows * (route.gate_for_slot.reshape(-1, 1) *
                     route.valid.reshape(-1, 1)).astype(g_out.dtype)
    rows = eo_flat[route.src]
    g_gate = jnp.einsum("tkd,td->tk", rows, g_out.astype(rows.dtype))
    g_gate = jnp.where(route.live, g_gate, 0.0).astype(gate_vals.dtype)
    return g_eo.astype(eo_flat.dtype), g_gate, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def _experts(eb, wg, wu, wd, dtype):
    g = jnp.einsum("becd,edf->becf", eb, wg)
    u = jnp.einsum("becd,edf->becf", eb, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("becf,efd->becd", h, wd)


def _aux_loss(route: Route, B: int, S: int, k: int, E: int):
    me = route.probs.mean(axis=(0, 1))                   # [E]
    onehot = jax.nn.one_hot(route.expert_idx.reshape(B, -1), E,
                            dtype=jnp.float32)
    ce = onehot.sum(axis=(0, 1)) / (B * S * k)
    return E * jnp.sum(me * ce)


def _mesh_info():
    try:
        env = jax._src.mesh.thread_resources.env  # noqa: SLF001
        mesh = env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:
        return None


def moe_layer(p: MoEParams, x, cfg: MoEConfig):
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    k, E = cfg.top_k, cfg.n_experts
    capacity = int(max(1, round(S * k / E * cfg.capacity_factor)))

    mesh = _mesh_info()
    use_shardmap = False
    if mesh is not None and "model" in mesh.axis_names:
        n_model = mesh.shape["model"]
        batch_axes = tuple(a for a in mesh.axis_names if a != "model")
        batch_width = 1
        for a in batch_axes:
            batch_width *= mesh.shape[a]
        # decode (S == 1) stays on the GSPMD path: the shard_map in_specs
        # would reshard the FSDP-laid-out expert weights (an all-gather of
        # the full expert stack PER TOKEN — measured 15x collective
        # regression on deepseek decode_32k, see EXPERIMENTS §Perf B);
        # with one token of routing work GSPMD's plan is already fine.
        use_shardmap = (E % n_model == 0 and B % batch_width == 0
                        and n_model > 1 and S > 1)

    if not use_shardmap:
        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p.router)
        route = jax.vmap(
            lambda xt, lg: _route_group(xt, lg, k, E, capacity))(x, logits)
        eb = jax.vmap(_dispatch)(x, route)               # [B, E, C, d]
        eo = _experts(eb, p.w_gate, p.w_up, p.w_down, x.dtype)
        out = jax.vmap(lambda e, r: _combine(
            e.reshape(E * capacity, d), r.gate_vals, r))(eo, route)
        aux = _aux_loss(route, B, S, k, E)
    else:
        from jax.sharding import PartitionSpec as P

        def body(xb, router, wg, wu, wd):
            e_loc = wg.shape[0]
            e0 = jax.lax.axis_index("model") * e_loc
            b_loc = xb.shape[0]
            logits = jnp.einsum("bsd,de->bse", xb.astype(jnp.float32),
                                router)
            route = jax.vmap(
                lambda xt, lg: _route_group(xt, lg, k, E, capacity))(
                    xb, logits)
            rloc = jax.vmap(lambda r: _localize(r, e0, e_loc, capacity))(
                route)
            ebl = jax.vmap(_dispatch)(xb, rloc)        # [B_loc,E_loc,C,d]
            eo = _experts(ebl, wg, wu, wd, xb.dtype)
            out_local = jax.vmap(lambda e, r: _combine(
                e.reshape(e_loc * capacity, d), r.gate_vals, r))(eo, rloc)
            out = jax.lax.psum(out_local, "model")     # activation-sized
            aux = _aux_loss(route, b_loc, S, k, E)
            for a in batch_axes:
                aux = jax.lax.pmean(aux, a)
            return out, aux

        # jax.shard_map is jax>=0.6; jax.experimental carries it (with the
        # pre-rename check_rep kwarg) on the 0.4.x line this image bakes in
        if hasattr(jax, "shard_map"):
            smap = functools.partial(jax.shard_map, check_vma=False)
        else:
            from jax.experimental.shard_map import shard_map as _shard_map
            smap = functools.partial(_shard_map, check_rep=False)
        out, aux = smap(
            body, mesh=mesh,
            in_specs=(P(batch_axes, None, None), P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=(P(batch_axes, None, None), P()),
        )(x, p.router, p.w_gate, p.w_up, p.w_down)

    if p.shared_gate is not None:
        gs = jnp.einsum("bsd,df->bsf", x, p.shared_gate)
        us = jnp.einsum("bsd,df->bsf", x, p.shared_up)
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype) * us
        out = out + jnp.einsum("bsf,fd->bsd", hs, p.shared_down)

    return out, aux
