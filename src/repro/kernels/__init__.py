"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2).

  xmv_dense           the paper's tiling & blocking on-the-fly Kronecker XMV
  xmv_block_sparse    inter-tile-sparse octile XMV (scalar prefetch)
  flash_attention     streaming attention for the LM zoo
  ops                 jit'd dispatch wrappers (auto-interpret off-TPU)
  ref                 pure-jnp oracles for all of the above
"""
from . import ops, ref
from .flash_attention import flash_attention
from .xmv_block_sparse import RowPanelPack, TilePack, pack_graph, \
    pack_graph_row_panels, pack_octiles, pack_row_panels, \
    xmv_block_sparse, xmv_gram_tile, xmv_row_panel, \
    xmv_row_panel_batched
from .xmv_dense import pick_tiles, xmv_dense, xmv_dense_batched

__all__ = [
    "ops", "ref", "flash_attention", "TilePack", "RowPanelPack",
    "pack_graph", "pack_octiles", "pack_row_panels",
    "pack_graph_row_panels", "xmv_block_sparse", "xmv_row_panel",
    "xmv_row_panel_batched", "xmv_gram_tile", "pick_tiles", "xmv_dense",
    "xmv_dense_batched",
]
