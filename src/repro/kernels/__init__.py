"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §2).

  xmv_dense           the paper's tiling & blocking on-the-fly Kronecker XMV
  xmv_block_sparse    inter-tile-sparse octile XMV (scalar prefetch)
  flash_attention     streaming attention for the LM zoo
  ops                 jit'd dispatch wrappers (auto-interpret off-TPU)
  ref                 pure-jnp oracles for all of the above
"""
from . import ops, ref
from .flash_attention import flash_attention
from .xmv_block_sparse import TilePack, pack_graph, pack_octiles, \
    xmv_block_sparse
from .xmv_dense import pick_tiles, xmv_dense, xmv_dense_batched

__all__ = [
    "ops", "ref", "flash_attention", "TilePack", "pack_graph",
    "pack_octiles", "xmv_block_sparse", "pick_tiles", "xmv_dense",
    "xmv_dense_batched",
]
