"""Dense on-the-fly Kronecker XMV — the paper's *tiling & blocking*
primitive (Sec. III-C / Appendix F), re-tiled for the TPU memory hierarchy.

Mapping from the CUDA kernel (DESIGN.md §2):

  CUDA                                  TPU (this kernel)
  ------------------------------------  --------------------------------
  t x t octile staged in shared memory  (TI x TJ) / (TIP x TJP) BlockSpec
                                        blocks staged in VMEM, double-
                                        buffered by the Pallas pipeline
  length-r register chunks              VREG-resident 4D broadcast tile
  warp lanes over product rows          VPU lanes over the (TIP, TJP) axes
  out block revisit via grid order      reduction grid dims innermost,
                                        @pl.when zero-init at step 0

For every output block y[I:I+TI, K:K+TIP] the kernel streams the J, L
contraction blocks of (A, E) and (A', E'), regenerates the product weights
    w = A[i,j] * A'[k,l] * kappa_e(E[i,j], E'[k,l])
in VMEM/VREGs (never in HBM — the paper's core idea), multiplies by the
P[j,l] block and accumulates. Arithmetic intensity grows with the tile
footprint exactly as the paper's Table I: global traffic per output block
is O((E+2F)/TILE^2) of the naive kernel's.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["xmv_dense", "xmv_dense_batched", "pick_tiles"]


def _kernel(*refs, edge_kernel, acc_dtype, fused, with_theta):
    """One grid step: o[TI, TIP] += contract((A,E) TIxTJ, (A',E') TIPxTJP,
    P TJxTJP). With ``fused``, the last reduction step instead emits the
    whole CG operator application diag*p - y for this output block
    (DESIGN.md §3). With ``with_theta`` the first input ref is a (1, P)
    hyperparameter vector and kappa is regenerated through
    ``edge_kernel.apply`` — how traced parameter values reach a kernel
    whose edge_kernel object is a static jit argument (DESIGN.md §7)."""
    if with_theta:
        t_ref, *refs = refs
    if fused:
        a_ref, e_ref, ap_ref, ep_ref, p_ref, diag_ref, pe_ref, o_ref = refs
    else:
        a_ref, e_ref, ap_ref, ep_ref, p_ref, o_ref = refs
        diag_ref = pe_ref = None
    j, l = pl.program_id(2), pl.program_id(3)

    @pl.when(jnp.logical_and(j == 0, l == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(acc_dtype)      # [TI, TJ]
    e = e_ref[...]                        # [TI, TJ]
    ap = ap_ref[...].astype(acc_dtype)    # [TIP, TJP]
    ep = ep_ref[...]                      # [TIP, TJP]
    p = p_ref[...].astype(acc_dtype)      # [TJ, TJP]
    # regenerate the product-matrix block on the fly: [TI, TJ, TIP, TJP]
    if with_theta:
        from repro.core.base_kernels import unpack_theta
        theta = unpack_theta(edge_kernel, t_ref[0])
        kappa = edge_kernel.apply(e[:, :, None, None],
                                  ep[None, None, :, :],
                                  theta).astype(acc_dtype)
    else:
        kappa = edge_kernel(e[:, :, None, None],
                            ep[None, None, :, :]).astype(acc_dtype)
    w = a[:, :, None, None] * ap[None, None, :, :] * kappa
    contrib = jnp.sum(w * p[None, :, None, :], axis=(1, 3))   # [TI, TIP]

    if not fused:
        o_ref[...] += contrib.astype(o_ref.dtype)
        return

    acc = o_ref[...] + contrib.astype(o_ref.dtype)
    last = jnp.logical_and(j == pl.num_programs(2) - 1,
                           l == pl.num_programs(3) - 1)

    @pl.when(last)
    def _epilogue():
        o_ref[...] = (diag_ref[...] * pe_ref[...]).astype(o_ref.dtype) - acc

    @pl.when(jnp.logical_not(last))
    def _accumulate():
        o_ref[...] = acc


def _divisor_tile(dim: int, target: int, quantum: int = 8) -> int:
    """Largest multiple of ``quantum`` that divides ``dim`` and is <=
    target; falls back to the largest plain divisor in [2, target]. A
    prime-ish ``dim`` whose only divisors are 1 and itself is rejected —
    the old behavior of returning ``dim`` silently blew the VMEM budget
    once the 4D regeneration tile scaled with it."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -quantum):
        if cand % quantum == 0 and dim % cand == 0:
            return cand
    if dim % quantum == 0:
        return quantum
    for cand in range(min(target, dim - 1), 1, -1):
        if dim % cand == 0:
            return cand
    raise ValueError(
        f"dim={dim} has no tile divisor in [2, {target}]; pad the graph "
        f"batch to a multiple of {quantum} (e.g. batch_from_graphs("
        f"pad_to=...)) so the dense XMV kernel can tile it")


def pick_tiles(n: int, m: int) -> tuple[int, int, int, int]:
    """Tile-size policy (see EXPERIMENTS.md §Perf for its derivation).

    VMEM budget: the 4D regeneration tile TI*TJ*TIP*TJP*4B must stay well
    under VMEM (~16 MB less pipeline buffers). TJP rides the 128-lane axis;
    TI*TJ*TIP*TJP = 8*16*8*128 = 128K elements = 512 KB f32 by default.
    """
    ti = _divisor_tile(n, 8)
    tj = _divisor_tile(n, 16)
    tip = _divisor_tile(m, 8)
    tjp = _divisor_tile(m, 128)
    return ti, tj, tip, tjp


@functools.partial(
    jax.jit,
    static_argnames=("edge_kernel", "tiles", "interpret", "acc_dtype"))
def xmv_dense(A, E, Ap, Ep, P, edge_kernel, *, diag=None, tiles=None,
              interpret=None, acc_dtype=jnp.float32, theta=None):
    """Single-pair on-the-fly XMV. A,E: [n,n]; Ap,Ep: [m,m]; P: [n,m].

    With ``diag`` ([n, m]) the fused epilogue emits ``diag * P - y``
    in-kernel — the full CG operator application with no extra XLA op.

    ``theta`` ([P_theta] f32, ``core.base_kernels.pack_theta`` order)
    overrides the edge kernel's hyperparameters with traced values — the
    differentiable-MGK path (DESIGN.md §7). It rides as a tiny VMEM
    input, so one compiled kernel serves every parameter value."""
    n, m = A.shape[0], Ap.shape[0]
    if tiles is None:
        tiles = pick_tiles(n, m)
    ti, tj, tip, tjp = tiles
    if n % ti or n % tj or m % tip or m % tjp:
        raise ValueError(f"tiles {tiles} must divide shapes n={n}, m={m}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fused = diag is not None
    with_theta = theta is not None
    grid = (n // ti, m // tip, n // tj, m // tjp)
    in_specs = [
        pl.BlockSpec((ti, tj), lambda i, k, j, l: (i, j)),
        pl.BlockSpec((ti, tj), lambda i, k, j, l: (i, j)),
        pl.BlockSpec((tip, tjp), lambda i, k, j, l: (k, l)),
        pl.BlockSpec((tip, tjp), lambda i, k, j, l: (k, l)),
        pl.BlockSpec((tj, tjp), lambda i, k, j, l: (j, l)),
    ]
    inputs = [A, E, Ap, Ep, P]
    if with_theta:
        n_theta = theta.shape[-1]
        in_specs.insert(0, pl.BlockSpec((1, n_theta),
                                        lambda i, k, j, l: (0, 0)))
        inputs.insert(0, theta.reshape(1, n_theta))
    if fused:
        in_specs += [pl.BlockSpec((ti, tip), lambda i, k, j, l: (i, k)),
                     pl.BlockSpec((ti, tip), lambda i, k, j, l: (i, k))]
        inputs += [diag, P]
    out = pl.pallas_call(
        functools.partial(_kernel, edge_kernel=edge_kernel,
                          acc_dtype=acc_dtype, fused=fused,
                          with_theta=with_theta),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((ti, tip), lambda i, k, j, l: (i, k)),
        out_shape=jax.ShapeDtypeStruct((n, m), P.dtype),
        interpret=interpret,
    )(*inputs)
    return out


def xmv_dense_batched(A, E, Ap, Ep, P, edge_kernel, *, diag=None,
                      tiles=None, interpret=None, theta=None):
    """Batched over pairs: leading axis B on every operand (the TPU
    analogue of 'many graph pairs per kernel launch', paper Sec. V).
    ``diag`` ([B, n, m], optional) selects the fused-epilogue kernel;
    ``theta`` ([P_theta], optional, shared across the batch) the traced
    edge-hyperparameter override."""
    fn = functools.partial(xmv_dense, edge_kernel=edge_kernel, tiles=tiles,
                           interpret=interpret)
    if diag is None:
        return jax.vmap(lambda a, e, ap, ep, p: fn(a, e, ap, ep, p,
                                                   theta=theta))(
            A, E, Ap, Ep, P)
    return jax.vmap(lambda a, e, ap, ep, p, d: fn(a, e, ap, ep, p, diag=d,
                                                  theta=theta))(
        A, E, Ap, Ep, P, diag)
