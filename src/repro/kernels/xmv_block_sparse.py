"""Block-sparse on-the-fly Kronecker XMV over non-empty octiles.

The TPU port of the paper's inter-tile sparsity exploitation (Sec. IV-A):
only non-empty octiles participate. The CUDA kernel streams a COO tile list
per warp and resolves output collisions with atomics; TPUs have neither
warps nor atomics, so (DESIGN.md §2):

* the COO list is re-bucketed BY TILE ROW at preprocessing time
  (``pack_octiles``), padded to the max tiles-per-row with pointers to a
  designated all-zero tile — zero contributions instead of control flow;
* the grid iterates (tile_row_i, tile_row_i', slot, slot'); the output
  block (i, i') is constant over the two inner reduction dims, so
  accumulation is race-free by construction (no atomics needed);
* the *dynamic* tile indirection uses scalar prefetch
  (PrefetchScalarGridSpec): the slot/column index arrays are prefetched to
  SMEM and drive the BlockSpec index_maps — the TPU-idiomatic equivalent of
  the warp reading COO coordinates.

Two launch granularities (DESIGN.md §3):

* :func:`xmv_block_sparse` — one pair per ``pallas_call``;
* :func:`xmv_block_sparse_batched` — a whole bucket of pairs per
  ``pallas_call``: the pair axis is folded into the grid as its leading
  (outermost) dimension and the prefetched index arrays carry a [B]
  axis, so one launch sweeps every pair (the paper Sec. V "many pairs
  per kernel launch", without B separate dispatches).

Both support a **fused diagonal epilogue**: pass ``diag = D_x V_x^{-1}``
(reshaped [n, m] / [B, n, m]) and the kernel emits the full CG operator
application ``diag * p - y`` in the output block's final grid step —
no extra XLA op or HBM round-trip per CG iteration (DESIGN.md §3).

Intra-tile sparsity (Sec. IV-B, bitmap compaction) lives at the storage
level: HBM holds only packed non-empty tiles; the kernel computes on dense
t x t blocks after VMEM expansion, mirroring the paper's "stored compact,
expanded in shared memory".
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.octile import OctileSet, octile_decompose

__all__ = ["TilePack", "pack_octiles", "xmv_block_sparse",
           "xmv_block_sparse_batched"]


class TilePack(NamedTuple):
    """Device-side row-bucketed octile storage for one graph.

    values_adj/values_lab: [K+1, t, t] packed non-empty tiles; slot K is
      all-zero (the padding target).
    slot: [n_tile_rows, k_max] int32 -> index into values_*.
    col:  [n_tile_rows, k_max] int32 tile-column (P block index).

    Stacked packs (``ops.stack_packs``) carry a leading [B] axis on every
    field and feed :func:`xmv_block_sparse_batched`.
    """
    values_adj: jnp.ndarray
    values_lab: jnp.ndarray
    slot: jnp.ndarray
    col: jnp.ndarray

    @property
    def tile(self) -> int:
        return self.values_adj.shape[-1]

    @property
    def n_tile_rows(self) -> int:
        return self.slot.shape[-2]


def pack_octiles(oset: OctileSet, k_max: int | None = None) -> TilePack:
    """Host-side: bucket an OctileSet's COO list by tile row."""
    t, nt = oset.tile, oset.n_tiles_side
    K_total = oset.coords.shape[0]       # includes padded() slots, if any
    real = oset.coords[:, 0] >= 0        # padded() marks pad slots with -1
    K = int(real.sum())
    rows = oset.coords[:K, 0]
    counts = np.bincount(rows, minlength=nt) if K else np.zeros(nt, np.int64)
    if k_max is None:
        k_max = max(int(counts.max(initial=0)), 1)
    elif counts.max(initial=0) > k_max:
        raise ValueError(f"k_max={k_max} < max tiles per row {counts.max()}")
    slot = np.full((nt, k_max), K_total, np.int32)   # K_total = zero tile
    col = np.zeros((nt, k_max), np.int32)
    fill = np.zeros(nt, np.int64)
    for k in range(K):
        r, c = oset.coords[k]
        slot[r, fill[r]] = k
        col[r, fill[r]] = c
        fill[r] += 1
    vals_a = np.concatenate(
        [oset.values_adj, np.zeros((1, t, t), np.float32)], axis=0)
    vals_e = np.concatenate(
        [oset.values_lab, np.zeros((1, t, t), np.float32)], axis=0)
    return TilePack(values_adj=jnp.asarray(vals_a),
                    values_lab=jnp.asarray(vals_e),
                    slot=jnp.asarray(slot), col=jnp.asarray(col))


def pack_graph(adjacency, edge_labels=None, tile: int = 8,
               k_max: int | None = None) -> TilePack:
    """Convenience: dense matrix -> TilePack."""
    return pack_octiles(octile_decompose(np.asarray(adjacency),
                                         None if edge_labels is None
                                         else np.asarray(edge_labels),
                                         tile=tile), k_max=k_max)


def _contrib(a, e, ap, ep, p, edge_kernel, acc_dtype):
    """One octile-pair contribution: contract the regenerated [t,t,t,t]
    product-weight block with the [t, t] P block -> [t, t]."""
    kappa = edge_kernel(e[:, :, None, None],
                        ep[None, None, :, :]).astype(acc_dtype)
    w = a[:, :, None, None] * ap[None, None, :, :] * kappa
    return jnp.sum(w * p[None, :, None, :], axis=(1, 3))


def _kernel(slot_a, col_a, slot_b, col_b,   # scalar-prefetch refs
            *refs, edge_kernel, acc_dtype, fused, batched):
    """Shared kernel body for the per-pair and batched grids.

    Grid layout: (nt, mt, ka, kb) per-pair, (B, nt, mt, ka, kb) batched;
    the two trailing dims are the reduction over octile slots, so the
    output block is revisited consecutively and accumulation is race-free.
    """
    d = 1 if batched else 0
    kk, kkp = pl.program_id(2 + d), pl.program_id(3 + d)
    n_kk, n_kkp = pl.num_programs(2 + d), pl.num_programs(3 + d)
    if fused:
        a_ref, e_ref, ap_ref, ep_ref, p_ref, diag_ref, pe_ref, o_ref = refs
    else:
        a_ref, e_ref, ap_ref, ep_ref, p_ref, o_ref = refs
        diag_ref = pe_ref = None

    @pl.when(jnp.logical_and(kk == 0, kkp == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    if batched:
        a, e = a_ref[0, 0].astype(acc_dtype), e_ref[0, 0]
        ap, ep = ap_ref[0, 0].astype(acc_dtype), ep_ref[0, 0]
        p = p_ref[0].astype(acc_dtype)
    else:
        a, e = a_ref[0].astype(acc_dtype), e_ref[0]
        ap, ep = ap_ref[0].astype(acc_dtype), ep_ref[0]
        p = p_ref[...].astype(acc_dtype)
    contrib = _contrib(a, e, ap, ep, p, edge_kernel,
                       acc_dtype).astype(o_ref.dtype)
    if batched:
        contrib = contrib[None]

    if not fused:
        o_ref[...] += contrib
        return

    acc = o_ref[...] + contrib
    last = jnp.logical_and(kk == n_kk - 1, kkp == n_kkp - 1)

    @pl.when(last)
    def _epilogue():
        # final grid step owns the completed y block: emit diag*p - y
        o_ref[...] = (diag_ref[...] * pe_ref[...]).astype(o_ref.dtype) - acc

    @pl.when(jnp.logical_not(last))
    def _accumulate():
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("edge_kernel", "interpret",
                                             "acc_dtype"))
def xmv_block_sparse(pack1: TilePack, pack2: TilePack, P, edge_kernel, *,
                     diag=None, interpret=None, acc_dtype=jnp.float32):
    """y = (A (x) A' .* E (x)k E') P using only non-empty octiles.

    With ``diag`` ([n, m]) the kernel instead returns the fused CG operator
    application ``diag * P - y`` (epilogue in the last reduction step).

    Work: O(K1_max_row * K2_max_row * nt * mt * t^4) vs the dense kernel's
    O(n^2 m^2) — the paper's Fig. 9 'Sparse' rung.
    """
    t = pack1.tile
    nt, mt = pack1.n_tile_rows, pack2.n_tile_rows
    ka, kb = pack1.slot.shape[1], pack2.slot.shape[1]
    n, m = P.shape
    if n != nt * t or m != mt * t:
        raise ValueError(f"P shape {P.shape} inconsistent with tile packs"
                         f" ({nt}x{t}, {mt}x{t})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fused = diag is not None

    in_specs = [
        pl.BlockSpec((1, t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (sa[i, kk], 0, 0)),
        pl.BlockSpec((1, t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (sa[i, kk], 0, 0)),
        pl.BlockSpec((1, t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (sb[ip, kkp], 0, 0)),
        pl.BlockSpec((1, t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (sb[ip, kkp], 0, 0)),
        pl.BlockSpec((t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (ca[i, kk], cb[ip, kkp])),
    ]
    inputs = [pack1.values_adj, pack1.values_lab,
              pack2.values_adj, pack2.values_lab, P]
    if fused:
        out_map = lambda i, ip, kk, kkp, sa, ca, sb, cb: (i, ip)  # noqa
        in_specs += [pl.BlockSpec((t, t), out_map),   # diag block
                     pl.BlockSpec((t, t), out_map)]   # P at the OUT block
        inputs += [diag, P]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nt, mt, ka, kb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (t, t), lambda i, ip, kk, kkp, sa, ca, sb, cb: (i, ip)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, edge_kernel=edge_kernel,
                          acc_dtype=acc_dtype, fused=fused, batched=False),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), P.dtype),
        interpret=interpret,
    )(pack1.slot, pack1.col, pack2.slot, pack2.col, *inputs)
    return out


@functools.partial(jax.jit, static_argnames=("edge_kernel", "interpret",
                                             "acc_dtype"))
def xmv_block_sparse_batched(packs1: TilePack, packs2: TilePack, P,
                             edge_kernel, *, diag=None, interpret=None,
                             acc_dtype=jnp.float32):
    """Whole-bucket block-sparse XMV in ONE ``pallas_call``.

    ``packs1``/``packs2`` are stacked TilePacks (``ops.stack_packs``) with a
    leading [B] axis on every field; ``P`` is [B, n, m]. The pair axis is
    the outermost grid dimension and the scalar-prefetch index maps select
    per-pair tiles via ``slot[b, i, k]`` — replacing B dispatches (and B
    jit boundaries) per CG iteration with one (paper Sec. V).

    With ``diag`` ([B, n, m]) the fused epilogue emits ``diag * P - y``.
    """
    B = packs1.values_adj.shape[0]
    t = packs1.values_adj.shape[-1]
    nt, mt = packs1.slot.shape[1], packs2.slot.shape[1]
    ka, kb = packs1.slot.shape[2], packs2.slot.shape[2]
    Bp, n, m = P.shape
    if Bp != B:
        raise ValueError(f"P batch {Bp} != pack batch {B}")
    if n != nt * t or m != mt * t:
        raise ValueError(f"P shape {P.shape} inconsistent with tile packs"
                         f" ({nt}x{t}, {mt}x{t})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fused = diag is not None

    in_specs = [
        pl.BlockSpec((1, 1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, sa[b, i, kk], 0, 0)),
        pl.BlockSpec((1, 1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, sa[b, i, kk], 0, 0)),
        pl.BlockSpec((1, 1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, sb[b, ip, kkp], 0, 0)),
        pl.BlockSpec((1, 1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, sb[b, ip, kkp], 0, 0)),
        pl.BlockSpec((1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, ca[b, i, kk], cb[b, ip, kkp])),
    ]
    inputs = [packs1.values_adj, packs1.values_lab,
              packs2.values_adj, packs2.values_lab, P]
    if fused:
        out_map = lambda b, i, ip, kk, kkp, sa, ca, sb, cb: (b, i, ip)  # noqa
        in_specs += [pl.BlockSpec((1, t, t), out_map),
                     pl.BlockSpec((1, t, t), out_map)]
        inputs += [diag, P]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, nt, mt, ka, kb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, t, t), lambda b, i, ip, kk, kkp, sa, ca, sb, cb: (b, i, ip)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, edge_kernel=edge_kernel,
                          acc_dtype=acc_dtype, fused=fused, batched=True),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n, m), P.dtype),
        interpret=interpret,
    )(packs1.slot, packs1.col, packs2.slot, packs2.col, *inputs)
    return out
