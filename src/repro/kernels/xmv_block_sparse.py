"""Block-sparse on-the-fly Kronecker XMV over non-empty octiles.

The TPU port of the paper's inter-tile sparsity exploitation (Sec. IV-A):
only non-empty octiles participate. The CUDA kernel streams a COO tile list
per warp, stages the streamed tiles in *shared memory* so every warp lane
reuses them, and resolves output collisions with atomics; TPUs have neither
warps nor atomics, so (DESIGN.md §2):

* the COO list is re-bucketed BY TILE ROW at preprocessing time into
  contiguous **row panels** (``pack_row_panels``) — the whole tile row
  (values + columns) lands in VMEM as ONE pipelined block fetch and is
  reused across every slot pair of the output block, the TPU analog of
  the paper's warp-shared tiles;
* the grid is (pair, tile_row_i, tile_row_i'): each output block is
  owned by exactly one grid step, so accumulation is race-free by
  construction (no atomics needed) and the (slot, slot') reduction runs
  as an in-kernel ``fori_loop`` whose trip counts are the row's *actual*
  slot counts, prefetched to SMEM — padding slots cost a skipped loop
  iteration, not a full grid step (the warp's COO cursor, DESIGN.md §3);
* the *dynamic* tile-column indirection uses scalar prefetch
  (PrefetchScalarGridSpec): the column/count arrays are prefetched to
  SMEM and drive dynamic P-block loads inside the kernel.

Two compute modes per octile pair (paper Sec. IV-B's density-adaptive
primitive choice, re-targeted to the TPU's two compute units):

* **elementwise (VPU)** — regenerate the [t, t, t, t] product-weight
  block from ``kappa_e`` and contract on the vector unit; works for any
  edge kernel.
* **MXU low-rank contraction** — for edge kernels with a feature
  expansion ``kappa(x, y) = sum_r f_r(x) f_r(y)``, the pack precomputes
  per-octile weighted tiles ``w_r = a ∘ f_r(e)`` and each octile pair
  contracts as ``sum_r w_r @ P_blk @ w'_r^T`` — small matmuls on the
  systolic array instead of a t^4 broadcast tensor, which is also what
  makes tile sizes t ∈ {8, 16, 32} worthwhile (t = 32 feeds the MXU
  with 32x32 operands; the VPU path scales as t^4).

The paper's SECOND reuse level — "warps across a thread block can
further share tiles via the shared memory" — maps to the **Gram-tile**
kernel (:func:`xmv_gram_tile`, DESIGN.md §8): one row-panel pack per
AXIS of an I x J Gram tile (Bi row-graph packs + Bj column-graph packs,
not Bi*Bj pair packs), a (Bi, nt, Bj) grid whose inner pair axis reuses
graph i's VMEM-staged tile row across all Bj partners, and an in-kernel
output-tile-column loop that collapses the per-pair kernel's mt grid
axis.

Legacy launch granularities kept as benchmark baselines (DESIGN.md §3):

* :func:`xmv_block_sparse` — one pair per ``pallas_call``, unrolled
  (nt, mt, ka, kb) grid;
* :func:`xmv_block_sparse_batched` — whole bucket per ``pallas_call``,
  (B, nt, mt, ka, kb) grid: every (slot, slot') pair is a separate grid
  step that re-fetches its octiles.

All entry points support a **fused diagonal epilogue**: pass
``diag = D_x V_x^{-1}`` (reshaped [n, m] / [B, n, m]) and the kernel emits
the full CG operator application ``diag * p - y`` in the output block —
no extra XLA op or HBM round-trip per CG iteration (DESIGN.md §3).

Intra-tile sparsity (Sec. IV-B, bitmap compaction) lives at the storage
level: HBM holds only packed non-empty tiles; the kernel computes on dense
t x t blocks after VMEM expansion, mirroring the paper's "stored compact,
expanded in shared memory".
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.octile import OctileSet, octile_decompose

__all__ = ["TilePack", "pack_octiles", "xmv_block_sparse",
           "xmv_block_sparse_batched", "RowPanelPack", "pack_row_panels",
           "pack_graph_row_panels", "xmv_row_panel",
           "xmv_row_panel_batched", "xmv_gram_tile",
           "gram_tile_vmem_bytes", "device_weighted_pack"]


class TilePack(NamedTuple):
    """Device-side row-bucketed octile storage for one graph.

    values_adj/values_lab: [K+1, t, t] packed non-empty tiles; slot K is
      all-zero (the padding target).
    slot: [n_tile_rows, k_max] int32 -> index into values_*.
    col:  [n_tile_rows, k_max] int32 tile-column (P block index).
    values_grad: optional [K+1, P, R, t, t] per-parameter feature-
      derivative operands ``a ∘ ∂f_r(e)/∂θ`` (``pack_octiles`` with an
      expandable ``edge_kernel``), the adjoint-solve companion buffer
      (DESIGN.md §7). The legacy kernels below never read it; it exists
      so cached TilePacks can be converted to gradient-ready row-panel
      layouts without re-decomposing.

    Stacked packs (``ops.stack_packs``) carry a leading [B] axis on every
    field and feed :func:`xmv_block_sparse_batched`. This is the storage
    of the *legacy* unrolled-grid kernels; the row-panel kernels read the
    contiguous :class:`RowPanelPack` layout instead.
    """
    values_adj: jnp.ndarray
    values_lab: jnp.ndarray
    slot: jnp.ndarray
    col: jnp.ndarray
    values_grad: jnp.ndarray | None = None

    @property
    def tile(self) -> int:
        return self.values_adj.shape[-1]

    @property
    def n_tile_rows(self) -> int:
        return self.slot.shape[-2]


class RowPanelPack(NamedTuple):
    """Row-panel octile storage for one graph: tiles contiguous per row.

    values_adj/values_lab: [nt, k_max, t, t]; row i's real tiles occupy
      slots [0, count[i]) in COO column order, the rest are zero.
    values_w: [nt, k_max, R, t, t] precomputed MXU operands
      ``w_r = a ∘ f_r(e)`` when the pack was built with a
      feature-expandable edge kernel, else None.
    col:   [nt, k_max] int32 tile-column (P block index) per slot.
    count: [nt] int32 *actual* tiles in each row (the SMEM loop bound).
    values_grad: optional [nt, k_max, P, R, t, t] per-parameter
      derivative operands ``wg_r = a ∘ ∂f_r(e)/∂θ_p``
      (``pack_row_panels(..., with_grad=True)``; P indexes
      ``edge_kernel.param_names()``). The adjoint edge-gradient
      contraction runs the SAME MXU kernel at rank 2R with the slot
      operands ``[wg ; w]`` vs ``[w' ; wg']`` (DESIGN.md §7) — exact
      edge-kernel gradients with A's sparsity, never densified.

    Stacked packs (``ops.stack_row_panel_packs``) carry a leading [B]
    axis on every field and feed :func:`xmv_row_panel_batched`. Unlike
    :class:`TilePack` there is no slot indirection: the panel layout IS
    the schedule, so the Pallas pipeline stages a whole tile row into
    VMEM as one block and the kernel reuses it across all slot pairs.

    VMEM envelope: the row-panel kernels also keep the pair's whole P
    panel resident (4*n*m bytes, fetched once per pair and reused by
    every output block), plus the two row panels
    (4*k_max*(2 or R)*t^2 bytes each). Graph-kernel buckets are far
    below the ~16 MB/core budget (n = m = 512 => 1 MB for P); buckets
    beyond n*m ~ 2M elements should fall back to the legacy
    :func:`xmv_block_sparse_batched`, whose P BlockSpec streams t x t
    blocks via prefetch-indexed maps instead.
    """
    values_adj: jnp.ndarray
    values_lab: jnp.ndarray
    values_w: jnp.ndarray | None
    col: jnp.ndarray
    count: jnp.ndarray
    values_grad: jnp.ndarray | None = None

    @property
    def tile(self) -> int:
        return self.values_adj.shape[-1]

    @property
    def n_tile_rows(self) -> int:
        return self.col.shape[-2]

    @property
    def k_max(self) -> int:
        return self.col.shape[-1]

    @property
    def rank(self) -> int | None:
        return None if self.values_w is None else self.values_w.shape[-3]


def _row_positions(rows: np.ndarray, nt: int) -> tuple[np.ndarray,
                                                       np.ndarray]:
    """Per-row slot position of each (row-major sorted) COO entry.

    Returns (counts[nt], pos[K]); vectorized replacement for the
    per-tile Python fill loop (runs once per graph per Gram block).
    """
    K = rows.shape[0]
    counts = np.bincount(rows, minlength=nt) if K else np.zeros(nt,
                                                                np.int64)
    starts = np.zeros(nt + 1, np.int64)
    starts[1:] = np.cumsum(counts)
    pos = np.arange(K, dtype=np.int64) - starts[rows]
    return counts, pos


def pack_octiles(oset: OctileSet, k_max: int | None = None,
                 edge_kernel=None) -> TilePack:
    """Host-side: bucket an OctileSet's COO list by tile row.

    With a feature-expandable ``edge_kernel`` the pack also carries the
    per-parameter ``values_grad`` derivative operands (see
    :class:`TilePack`)."""
    t, nt = oset.tile, oset.n_tiles_side
    K_total = oset.coords.shape[0]       # includes padded() slots, if any
    real = oset.coords[:, 0] >= 0        # padded() marks pad slots with -1
    K = int(real.sum())
    rows = oset.coords[:K, 0].astype(np.int64)
    cols = oset.coords[:K, 1]
    counts, pos = _row_positions(rows, nt)
    if k_max is None:
        k_max = max(int(counts.max(initial=0)), 1)
    elif counts.max(initial=0) > k_max:
        raise ValueError(f"k_max={k_max} < max tiles per row {counts.max()}")
    slot = np.full((nt, k_max), K_total, np.int32)   # K_total = zero tile
    col = np.zeros((nt, k_max), np.int32)
    slot[rows, pos] = np.arange(K, dtype=np.int32)
    col[rows, pos] = cols
    vals_a = np.concatenate(
        [oset.values_adj, np.zeros((1, t, t), np.float32)], axis=0)
    vals_e = np.concatenate(
        [oset.values_lab, np.zeros((1, t, t), np.float32)], axis=0)
    vg = None
    if edge_kernel is not None and edge_kernel.feature_rank() is not None \
            and edge_kernel.param_names():
        from repro.core.octile import feature_operands
        _, wg = feature_operands(vals_a, vals_e, edge_kernel,
                                 with_grad=True)   # [K+1, P, R, t, t]
        vg = jnp.asarray(np.asarray(wg, np.float32))
    return TilePack(values_adj=jnp.asarray(vals_a),
                    values_lab=jnp.asarray(vals_e),
                    slot=jnp.asarray(slot), col=jnp.asarray(col),
                    values_grad=vg)


def resolve_pack_dtype(pack_dtype):
    """Normalize the ``pack_dtype`` knob to a numpy dtype (None -> f32;
    "bfloat16" strings resolve through jax's ml_dtypes registration)."""
    if pack_dtype is None:
        return np.dtype(np.float32)
    if isinstance(pack_dtype, str) and pack_dtype == "bfloat16":
        return np.dtype(jnp.bfloat16)
    return np.dtype(pack_dtype)


def pack_row_panels(oset: OctileSet, edge_kernel=None,
                    k_max: int | None = None,
                    as_numpy: bool = False,
                    with_grad: bool = False,
                    pack_dtype=None) -> RowPanelPack:
    """Host-side: lay an OctileSet out as contiguous VMEM-ready row panels.

    With ``edge_kernel`` carrying a feature expansion
    (``feature_rank() is not None``), the pack also precomputes the MXU
    operands ``w_r = a ∘ f_r(e)`` per octile — loop-invariant across the
    whole CG solve, so weighting at pack time amortizes it over every
    matvec (the same trade the dense low-rank path makes in
    ``core/mgk.py``). ``with_grad`` additionally fills ``values_grad``
    with the per-parameter derivative operands ``a ∘ ∂f_r(e)/∂θ`` —
    loop-invariant across the adjoint contraction the same way
    (DESIGN.md §7).

    ``as_numpy`` keeps the fields as host arrays (for caching layers that
    re-pad and stack before the single device transfer).

    ``pack_dtype`` stores the VALUE buffers (``values_adj`` /
    ``values_lab`` / ``values_w`` / ``values_grad``) in a narrower
    dtype — ``jnp.bfloat16`` halves the HBM bytes every matvec streams
    while the kernels keep f32 accumulators (operands are upcast in
    VMEM before compute; DESIGN.md §9.4). Index/count arrays stay
    int32. f32 packing is bit-exact as before.
    """
    dtype = resolve_pack_dtype(pack_dtype)
    t, nt = oset.tile, oset.n_tiles_side
    real = oset.coords[:, 0] >= 0
    rows = oset.coords[real, 0].astype(np.int64)
    cols = oset.coords[real, 1]
    vals_a = oset.values_adj[real]
    vals_e = oset.values_lab[real]
    counts, pos = _row_positions(rows, nt)
    if k_max is None:
        k_max = max(int(counts.max(initial=0)), 1)
    elif counts.max(initial=0) > k_max:
        raise ValueError(f"k_max={k_max} < max tiles per row {counts.max()}")
    va = np.zeros((nt, k_max, t, t), dtype)
    ve = np.zeros((nt, k_max, t, t), dtype)
    col = np.zeros((nt, k_max), np.int32)
    va[rows, pos] = vals_a.astype(dtype)
    ve[rows, pos] = vals_e.astype(dtype)
    col[rows, pos] = cols
    vw = vg = None
    if edge_kernel is not None and edge_kernel.feature_rank() is not None:
        from repro.core.octile import feature_operands
        with_grad = with_grad and bool(edge_kernel.param_names())
        # operand derivation runs in f32; only the STORED buffers narrow
        w, wg = feature_operands(vals_a, vals_e, edge_kernel,
                                 with_grad=with_grad)
        R = w.shape[-3]
        vw = np.zeros((nt, k_max, R, t, t), dtype)
        vw[rows, pos] = np.asarray(w, np.float32).astype(dtype)
        if wg is not None:
            P = wg.shape[-4]
            vg = np.zeros((nt, k_max, P, R, t, t), dtype)
            vg[rows, pos] = np.asarray(wg, np.float32).astype(dtype)
    dev = (lambda x: x) if as_numpy else jnp.asarray
    opt = lambda x: None if x is None else dev(x)   # noqa: E731
    return RowPanelPack(values_adj=dev(va),
                        values_lab=dev(ve),
                        values_w=opt(vw),
                        col=dev(col),
                        count=dev(counts.astype(np.int32)),
                        values_grad=opt(vg))


def pack_graph(adjacency, edge_labels=None, tile: int = 8,
               k_max: int | None = None) -> TilePack:
    """Convenience: dense matrix -> TilePack."""
    return pack_octiles(octile_decompose(np.asarray(adjacency),
                                         None if edge_labels is None
                                         else np.asarray(edge_labels),
                                         tile=tile), k_max=k_max)


def pack_graph_row_panels(adjacency, edge_labels=None, tile: int = 8,
                          edge_kernel=None, k_max: int | None = None,
                          with_grad: bool = False,
                          pack_dtype=None) -> RowPanelPack:
    """Convenience: dense matrix -> RowPanelPack."""
    return pack_row_panels(
        octile_decompose(np.asarray(adjacency),
                         None if edge_labels is None
                         else np.asarray(edge_labels), tile=tile),
        edge_kernel=edge_kernel, k_max=k_max, with_grad=with_grad,
        pack_dtype=pack_dtype)


def device_weighted_pack(pack: RowPanelPack, edge_kernel, theta=None,
                         with_grad: bool = False) -> RowPanelPack:
    """Recompute a pack's weighted operands ON DEVICE from its structural
    fields: ``values_w = a ∘ f_r(e; theta)`` (and ``values_grad`` when
    ``with_grad``). Works on per-graph and stacked ([B]-leading) packs.

    This is how traced hyperparameters reach the MXU contraction mode,
    whose kernel consumes pre-weighted tiles as plain data: the pack-time
    host precompute bakes the kernel's static parameter values in, so the
    differentiable path re-derives the operands from ``values_lab`` once
    per solve — O(nnz·R) work amortized over every CG iteration, leaving
    the Pallas kernel untouched (DESIGN.md §7). bf16-stored packs
    (``pack_dtype``) upcast before derivation so the feature math and
    the resulting operands stay f32."""
    from repro.core.octile import feature_operands
    w, wg = feature_operands(pack.values_adj.astype(jnp.float32),
                             pack.values_lab.astype(jnp.float32),
                             edge_kernel, theta=theta,
                             with_grad=with_grad)
    return pack._replace(values_w=w, values_grad=wg)


def _contrib(a, e, ap, ep, p, edge_kernel, acc_dtype, theta=None):
    """One octile-pair contribution: contract the regenerated [t,t,t,t]
    product-weight block with the [t, t] P block -> [t, t].

    Operands are upcast to the accumulator dtype BEFORE any product so
    bf16-streamed packs (``pack_dtype``) regenerate edge-kernel values
    and adjacency products in f32 — storage precision costs one
    rounding of the inputs, never compounded kernel math (re-cast here
    so the contract holds regardless of caller-side casts)."""
    a = a.astype(acc_dtype)
    ap = ap.astype(acc_dtype)
    e = e.astype(acc_dtype)
    ep = ep.astype(acc_dtype)
    if theta is None:
        kappa = edge_kernel(e[:, :, None, None], ep[None, None, :, :])
    else:
        kappa = edge_kernel.apply(e[:, :, None, None],
                                  ep[None, None, :, :], theta)
    kappa = kappa.astype(acc_dtype)
    w = a[:, :, None, None] * ap[None, None, :, :] * kappa
    return jnp.sum(w * p[None, :, None, :], axis=(1, 3))


def _mxu_contrib(w, wp, p, acc_dtype):
    """One octile-pair contribution on the MXU: sum_r w_r @ P @ w'_r^T.

    w/wp: [R, t, t] pre-weighted tiles ``a ∘ f_r(e)``; p: [t, t].
    Two rank-batched matmuls replace the t^4 broadcast tensor.
    Operands upcast to the accumulator dtype (bf16 ``pack_dtype``
    streams half the HBM bytes; the MXU contraction stays f32).
    """
    w = w.astype(acc_dtype)
    wp = wp.astype(acc_dtype)
    tmp = jax.lax.dot_general(            # [R, t, t]: w_r @ P
        w, p, (((2,), (0,)), ((), ())), preferred_element_type=acc_dtype)
    out = jax.lax.dot_general(            # [R, t, t]: (w_r @ P) @ w'_r^T
        tmp, wp, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=acc_dtype)
    return jnp.sum(out, axis=0)


def _row_panel_kernel(col1, cnt1, col2, cnt2,   # scalar-prefetch refs
                      *refs, edge_kernel, acc_dtype, fused, mxu, batched,
                      tile, rank, with_theta):
    """Row-panel kernel body: one grid step OWNS output block (i, i').

    Grid layout: (nt, mt) per-pair, (B, nt, mt) batched. Both graphs'
    whole tile rows are VMEM-resident (one pipelined block fetch each)
    and reused across all ka x kb slot pairs; the slot reduction is an
    in-kernel ``fori_loop`` bounded by the rows' SMEM slot counts, so
    padding slots are never touched. Each output block is written
    exactly once — no cross-step accumulation, no init/epilogue grid
    predicates.

    ``with_theta`` (elementwise mode only): the first regular input is a
    (1, P) hyperparameter vector and kappa is regenerated through
    ``edge_kernel.apply`` — traced parameter values reaching a kernel
    whose edge_kernel is a static jit argument (DESIGN.md §7).
    """
    t = tile
    d = 1 if batched else 0
    i, ip = pl.program_id(d), pl.program_id(d + 1)
    theta = None
    if with_theta:
        from repro.core.base_kernels import unpack_theta
        t_ref, *refs = refs
        theta = unpack_theta(edge_kernel, t_ref[0])
    if mxu:
        w1_ref, w2_ref, p_ref = refs[:3]
        rest = refs[3:]
    else:
        a1_ref, e1_ref, a2_ref, e2_ref, p_ref = refs[:5]
        rest = refs[5:]
    diag_ref, o_ref = (rest if fused else (None, rest[0]))

    if batched:
        b = pl.program_id(0)
        na, nb = cnt1[b, i], cnt2[b, ip]
        col_a = lambda k: col1[b, i, k]      # noqa: E731
        col_b = lambda k: col2[b, ip, k]     # noqa: E731
        at = lambda ref, k: ref[0, 0, k]     # noqa: E731
        atr = lambda ref, k: ref[0, 0, pl.ds(k * rank, rank)]  # noqa: E731
    else:
        na, nb = cnt1[i], cnt2[ip]
        col_a = lambda k: col1[i, k]         # noqa: E731
        col_b = lambda k: col2[ip, k]        # noqa: E731
        at = lambda ref, k: ref[0, k]        # noqa: E731
        atr = lambda ref, k: ref[0, pl.ds(k * rank, rank)]     # noqa: E731

    def p_block(ca, cb):
        blk = (p_ref[0, pl.ds(ca * t, t), pl.ds(cb * t, t)] if batched
               else p_ref[pl.ds(ca * t, t), pl.ds(cb * t, t)])
        return blk.astype(acc_dtype)

    def outer(kk, acc):
        ca = col_a(kk)
        if mxu:
            w = atr(w1_ref, kk)                      # [R, t, t], staged row
        else:
            a = at(a1_ref, kk).astype(acc_dtype)
            e = at(e1_ref, kk)

        def inner(kkp, acc):
            pblk = p_block(ca, col_b(kkp))
            if mxu:
                contrib = _mxu_contrib(w, atr(w2_ref, kkp), pblk, acc_dtype)
            else:
                contrib = _contrib(a, e, at(a2_ref, kkp).astype(acc_dtype),
                                   at(e2_ref, kkp), pblk, edge_kernel,
                                   acc_dtype, theta=theta)
            return acc + contrib

        return jax.lax.fori_loop(0, nb, inner, acc)

    acc = jax.lax.fori_loop(0, na, outer,
                            jnp.zeros((t, t), acc_dtype))

    if fused:
        # the operator application diag*p - y, with the p block read from
        # the already-VMEM-resident P panel
        dblk = (diag_ref[0] if batched else diag_ref[...]).astype(acc_dtype)
        pout = p_block(i, ip)
        acc = dblk * pout - acc
    res = acc.astype(o_ref.dtype)
    o_ref[...] = res[None] if batched else res


def _resolve_mode(mode: str, packs1: RowPanelPack,
                  packs2: RowPanelPack) -> bool:
    """Map the mode knob to the mxu flag, validating pack contents."""
    have_w = packs1.values_w is not None and packs2.values_w is not None
    if mode == "auto":
        return have_w
    if mode == "mxu":
        if not have_w:
            raise ValueError(
                "mode='mxu' needs packs built with a feature-expandable"
                " edge kernel (pack_row_panels(..., edge_kernel=...))")
        return True
    if mode == "elementwise":
        return False
    raise ValueError(f"unknown row-panel mode {mode!r}")


def _row_panel_call(packs1, packs2, P, edge_kernel, diag, interpret,
                    acc_dtype, mode, batched, theta=None):
    t = packs1.tile
    nt, mt = packs1.n_tile_rows, packs2.n_tile_rows
    ka, kb = packs1.k_max, packs2.k_max
    if batched:
        B = packs1.col.shape[0]
        Bp, n, m = P.shape
        if Bp != B:
            raise ValueError(f"P batch {Bp} != pack batch {B}")
    else:
        n, m = P.shape
    if n != nt * t or m != mt * t:
        raise ValueError(f"P shape {P.shape} inconsistent with tile packs"
                         f" ({nt}x{t}, {mt}x{t})")
    if packs2.tile != t:
        raise ValueError(f"tile mismatch: {t} vs {packs2.tile}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fused = diag is not None
    mxu = _resolve_mode(mode, packs1, packs2)
    rank = packs1.rank if mxu else 0
    if mxu and packs2.rank != rank:
        raise ValueError(
            f"feature rank mismatch: {rank} vs {packs2.rank}")

    if batched:
        def panel1(shape):
            return pl.BlockSpec((1, 1) + shape,
                                lambda b, i, ip, c1, n1, c2, n2:
                                (b, i) + (0,) * len(shape))

        def panel2(shape):
            return pl.BlockSpec((1, 1) + shape,
                                lambda b, i, ip, c1, n1, c2, n2:
                                (b, ip) + (0,) * len(shape))

        p_spec = pl.BlockSpec((1, n, m),
                              lambda b, i, ip, c1, n1, c2, n2: (b, 0, 0))
        out_spec = pl.BlockSpec((1, t, t),
                                lambda b, i, ip, c1, n1, c2, n2: (b, i, ip))
        grid = (B, nt, mt)
        out_shape = jax.ShapeDtypeStruct((B, n, m), P.dtype)
    else:
        def panel1(shape):
            return pl.BlockSpec((1,) + shape,
                                lambda i, ip, c1, n1, c2, n2:
                                (i,) + (0,) * len(shape))

        def panel2(shape):
            return pl.BlockSpec((1,) + shape,
                                lambda i, ip, c1, n1, c2, n2:
                                (ip,) + (0,) * len(shape))

        p_spec = pl.BlockSpec((n, m),
                              lambda i, ip, c1, n1, c2, n2: (0, 0))
        out_spec = pl.BlockSpec((t, t),
                                lambda i, ip, c1, n1, c2, n2: (i, ip))
        grid = (nt, mt)
        out_shape = jax.ShapeDtypeStruct((n, m), P.dtype)

    with_theta = theta is not None and not mxu
    if mxu:
        # [.., nt, ka, R, t, t] -> [.., nt, ka*R, t, t]: slot-major,
        # rank-minor, so slot kk's operands are rows [kk*R, (kk+1)*R)
        w1 = packs1.values_w.reshape(packs1.values_w.shape[:-4]
                                     + (ka * rank, t, t))
        w2 = packs2.values_w.reshape(packs2.values_w.shape[:-4]
                                     + (kb * rank, t, t))
        in_specs = [panel1((ka * rank, t, t)), panel2((kb * rank, t, t)),
                    p_spec]
        inputs = [w1, w2, P]
    else:
        in_specs = [panel1((ka, t, t)), panel1((ka, t, t)),
                    panel2((kb, t, t)), panel2((kb, t, t)), p_spec]
        inputs = [packs1.values_adj, packs1.values_lab,
                  packs2.values_adj, packs2.values_lab, P]
    if with_theta:
        n_theta = theta.shape[-1]
        in_specs.insert(0, pl.BlockSpec((1, n_theta), lambda *_: (0, 0)))
        inputs.insert(0, theta.reshape(1, n_theta))
    if fused:
        in_specs.append(out_spec)
        inputs.append(diag)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(_row_panel_kernel, edge_kernel=edge_kernel,
                          acc_dtype=acc_dtype, fused=fused, mxu=mxu,
                          batched=batched, tile=t, rank=rank,
                          with_theta=with_theta),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(packs1.col, packs1.count, packs2.col, packs2.count, *inputs)


@functools.partial(jax.jit, static_argnames=("edge_kernel", "interpret",
                                             "acc_dtype", "mode"))
def xmv_row_panel(pack1: RowPanelPack, pack2: RowPanelPack, P, edge_kernel,
                  *, diag=None, mode: str = "auto", interpret=None,
                  acc_dtype=jnp.float32, theta=None):
    """y = (A (x) A' .* E (x)k E') P via VMEM-staged row panels (one pair).

    ``mode``: "elementwise" (VPU, any edge kernel), "mxu" (low-rank
    contraction; needs packs built with the edge kernel), or "auto"
    (mxu iff both packs carry precomputed weighted tiles).

    With ``diag`` ([n, m]) the kernel instead returns the fused CG
    operator application ``diag * P - y``. ``theta`` ([P_theta] f32,
    ``pack_theta`` order) overrides the edge kernel's hyperparameters
    with traced values on the elementwise path; the MXU path takes its
    parameters through ``device_weighted_pack`` instead (DESIGN.md §7).
    """
    return _row_panel_call(pack1, pack2, P, edge_kernel, diag, interpret,
                           acc_dtype, mode, batched=False, theta=theta)


@functools.partial(jax.jit, static_argnames=("edge_kernel", "interpret",
                                             "acc_dtype", "mode"))
def xmv_row_panel_batched(packs1: RowPanelPack, packs2: RowPanelPack, P,
                          edge_kernel, *, diag=None, mode: str = "auto",
                          interpret=None, acc_dtype=jnp.float32,
                          theta=None):
    """Whole-bucket row-panel block-sparse XMV in ONE ``pallas_call``.

    ``packs1``/``packs2`` are stacked RowPanelPacks
    (``ops.stack_row_panel_packs``) with a leading [B] axis on every
    field; ``P`` is [B, n, m]. Grid (B, nt, mt): the pair axis is the
    outermost grid dimension, each output block is owned by one grid
    step, and the (slot, slot') reduction runs in-kernel over the
    VMEM-staged tile rows (vs a grid step per slot pair in the legacy
    :func:`xmv_block_sparse_batched`).

    With ``diag`` ([B, n, m]) the fused epilogue emits ``diag * P - y``;
    ``theta`` (shared across the bucket) as in :func:`xmv_row_panel`.
    """
    return _row_panel_call(packs1, packs2, P, edge_kernel, diag, interpret,
                           acc_dtype, mode, batched=True, theta=theta)


def _gram_tile_kernel(col1, cnt1, col2, cnt2,   # scalar-prefetch refs
                      *refs, edge_kernel, acc_dtype, fused, mxu, tile,
                      mt, rank, with_theta):
    """Gram-tile kernel body: one grid step owns the [t, m] output ROW
    STRIP of pair (bi, bj) at tile row i.

    Grid layout: (Bi, nt, Bj) — the COLUMN-graph pair axis is the grid's
    inner axis, so graph bi's VMEM-staged tile row (index map (bi, i),
    constant across the whole inner bj sweep) is fetched ONCE and reused
    by all Bj partners: the TPU-pipelining analog of the paper's
    "warps across a thread block share tiles via shared memory", lifted
    from slot pairs within one pair to the PAIR AXIS of a Gram tile.
    Graph bj arrives as its whole row-panel pack (all mt tile rows in
    one block), and the mt loop runs IN-KERNEL — mt-fold fewer grid
    steps than the per-pair row-panel kernel on the same work.

    Slot reductions stay bounded by the SMEM-prefetched actual counts;
    the fused epilogue emits the full operator strip diag*p - y from
    the already-resident P panel.
    """
    t = tile
    bi, i, bj = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    theta = None
    if with_theta:
        from repro.core.base_kernels import unpack_theta
        t_ref, *refs = refs
        theta = unpack_theta(edge_kernel, t_ref[0])
    if mxu:
        w1_ref, w2_ref, p_ref = refs[:3]
        rest = refs[3:]
    else:
        a1_ref, e1_ref, a2_ref, e2_ref, p_ref = refs[:5]
        rest = refs[5:]
    diag_ref, o_ref = (rest if fused else (None, rest[0]))

    na = cnt1[bi, i]
    m = mt * t

    def p_block(ca, cb):
        return p_ref[0, 0, pl.ds(ca * t, t),
                     pl.ds(cb * t, t)].astype(acc_dtype)

    def row_block(ip, strip):
        # output block (i, ip) of pair (bi, bj): the usual ka x kb slot
        # reduction, with graph bj's tile row read out of its whole
        # VMEM-resident pack at row ip
        nb = cnt2[bj, ip]

        def outer(kk, acc):
            ca = col1[bi, i, kk]
            if mxu:
                w = w1_ref[0, 0, pl.ds(kk * rank, rank)]     # [R, t, t]
            else:
                a = a1_ref[0, 0, kk].astype(acc_dtype)
                e = e1_ref[0, 0, kk]

            def inner(kkp, acc):
                pblk = p_block(ca, col2[bj, ip, kkp])
                if mxu:
                    wp = w2_ref[0, ip, pl.ds(kkp * rank, rank)]
                    contrib = _mxu_contrib(w, wp, pblk, acc_dtype)
                else:
                    contrib = _contrib(
                        a, e, a2_ref[0, ip, kkp].astype(acc_dtype),
                        e2_ref[0, ip, kkp], pblk, edge_kernel, acc_dtype,
                        theta=theta)
                return acc + contrib

            return jax.lax.fori_loop(0, nb, inner, acc)

        blk = jax.lax.fori_loop(0, na, outer,
                                jnp.zeros((t, t), acc_dtype))
        return jax.lax.dynamic_update_slice(strip, blk, (0, ip * t))

    strip = jax.lax.fori_loop(0, mt, row_block,
                              jnp.zeros((t, m), acc_dtype))
    if fused:
        # operator strip diag*p - y from the VMEM-resident P panel
        dstrip = diag_ref[0, 0].astype(acc_dtype)
        pstrip = p_ref[0, 0, pl.ds(i * t, t), :].astype(acc_dtype)
        strip = dstrip * pstrip - strip
    o_ref[0, 0] = strip.astype(o_ref.dtype)


def gram_tile_vmem_bytes(packs_i: RowPanelPack, packs_j: RowPanelPack,
                         mxu: bool) -> int:
    """Per-grid-step VMEM envelope of :func:`xmv_gram_tile` in bytes
    (x2 for the pipeline's double buffering): graph j's whole
    pack + graph i's tile row + the P panel + the diag/out strips.
    Pack operands are costed at their STORED itemsize — bf16 packs
    (``pack_dtype``) halve the operand share of the envelope, which is
    exactly what lets larger tiles stay on the Gram-tile kernel.
    ``gram_pair_step`` uses this to route over-budget buckets to the
    per-pair :func:`xmv_row_panel_batched` automatically."""
    t = packs_i.tile
    nt, mt = packs_i.n_tile_rows, packs_j.n_tile_rows
    ka, kb = packs_i.k_max, packs_j.k_max
    n, m = nt * t, mt * t
    ci = packs_i.rank if (mxu and packs_i.rank) else 2
    cj = packs_j.rank if (mxu and packs_j.rank) else 2
    pack_bytes = np.dtype(packs_i.values_adj.dtype).itemsize
    operands = (ka * ci * t * t          # graph i's tile row
                + mt * kb * cj * t * t)  # graph j's whole pack
    fp32 = (n * m                        # the pair's P panel
            + 2 * t * m)                 # diag + out strips
    return 2 * (pack_bytes * operands + 4 * fp32)  # double buffered


@functools.partial(jax.jit, static_argnames=("edge_kernel", "interpret",
                                             "acc_dtype", "mode"))
def xmv_gram_tile(packs_i: RowPanelPack, packs_j: RowPanelPack, P,
                  edge_kernel, *, diag=None, mode: str = "auto",
                  interpret=None, acc_dtype=jnp.float32, theta=None):
    """All Bi x Bj cross-pair XMVs of a Gram tile in ONE ``pallas_call``.

    ``packs_i``/``packs_j`` are stacked RowPanelPacks with a leading
    PER-AXIS batch — Bi packs for the row graphs and Bj for the column
    graphs, NOT Bi*Bj per-pair packs, so each graph's panels live in HBM
    exactly once per Gram tile. ``P`` is [Bi, Bj, n, m]; the result is
    the [Bi, Bj, n, m] stack of y = (A_i (x) A'_j .* E_i (x)k E'_j) P_ij.

    Grid (Bi, nt, Bj): graph i's tile row is fetched once per (bi, i)
    and reused across ALL Bj partners (the pair-axis operand reuse the
    paper gets from thread-block shared memory); graph j's whole
    row-panel pack is staged per step and the output-tile-column loop
    runs in-kernel, collapsing the per-pair kernel's mt grid axis.
    VMEM envelope per step (:func:`gram_tile_vmem_bytes`): graph j's
    pack (4*mt*kb*(2 or R)*t^2 bytes) + one P panel (4*n*m) + graph i's
    tile row — graph-kernel buckets sit far below the ~16 MB/core
    budget. This function does NOT guard the envelope itself; the Gram
    driver's ``gram_pair_step`` checks it and routes over-budget
    buckets to the per-pair :func:`xmv_row_panel_batched`.

    ``mode``/``diag``/``theta`` as in :func:`xmv_row_panel_batched`
    (``diag``: [Bi, Bj, n, m] fused CG epilogue; ``theta``: traced
    hyperparameter vector on the elementwise path).
    """
    t = packs_i.tile
    nt, mt = packs_i.n_tile_rows, packs_j.n_tile_rows
    ka, kb = packs_i.k_max, packs_j.k_max
    Bi, Bj = packs_i.col.shape[0], packs_j.col.shape[0]
    if P.ndim != 4:
        raise ValueError(f"P must be [Bi, Bj, n, m], got shape {P.shape}")
    Pi, Pj, n, m = P.shape
    if (Pi, Pj) != (Bi, Bj):
        raise ValueError(f"P pair axes {(Pi, Pj)} != pack axes"
                         f" {(Bi, Bj)}")
    if n != nt * t or m != mt * t:
        raise ValueError(f"P shape {P.shape} inconsistent with tile packs"
                         f" ({nt}x{t}, {mt}x{t})")
    if packs_j.tile != t:
        raise ValueError(f"tile mismatch: {t} vs {packs_j.tile}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fused = diag is not None
    mxu = _resolve_mode(mode, packs_i, packs_j)
    rank = packs_i.rank if mxu else 0
    if mxu and packs_j.rank != rank:
        raise ValueError(
            f"feature rank mismatch: {rank} vs {packs_j.rank}")

    def panel_i(shape):
        # ONE tile row of graph bi; constant across the inner bj axis
        return pl.BlockSpec((1, 1) + shape,
                            lambda bi, i, bj, c1, n1, c2, n2:
                            (bi, i) + (0,) * len(shape))

    def pack_j(shape):
        # the WHOLE row-panel pack of graph bj (all mt tile rows)
        return pl.BlockSpec((1,) + shape,
                            lambda bi, i, bj, c1, n1, c2, n2:
                            (bj,) + (0,) * len(shape))

    p_spec = pl.BlockSpec((1, 1, n, m),
                          lambda bi, i, bj, c1, n1, c2, n2:
                          (bi, bj, 0, 0))
    out_spec = pl.BlockSpec((1, 1, t, m),
                            lambda bi, i, bj, c1, n1, c2, n2:
                            (bi, bj, i, 0))

    with_theta = theta is not None and not mxu
    if mxu:
        # slot-major, rank-minor flattening, as in the row-panel kernel
        w1 = packs_i.values_w.reshape((Bi, nt, ka * rank, t, t))
        w2 = packs_j.values_w.reshape((Bj, mt, kb * rank, t, t))
        in_specs = [panel_i((ka * rank, t, t)),
                    pack_j((mt, kb * rank, t, t)), p_spec]
        inputs = [w1, w2, P]
    else:
        in_specs = [panel_i((ka, t, t)), panel_i((ka, t, t)),
                    pack_j((mt, kb, t, t)), pack_j((mt, kb, t, t)),
                    p_spec]
        inputs = [packs_i.values_adj, packs_i.values_lab,
                  packs_j.values_adj, packs_j.values_lab, P]
    if with_theta:
        n_theta = theta.shape[-1]
        in_specs.insert(0, pl.BlockSpec((1, n_theta), lambda *_: (0, 0)))
        inputs.insert(0, theta.reshape(1, n_theta))
    if fused:
        in_specs.append(out_spec)
        inputs.append(diag)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(Bi, nt, Bj),
        in_specs=in_specs,
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(_gram_tile_kernel, edge_kernel=edge_kernel,
                          acc_dtype=acc_dtype, fused=fused, mxu=mxu,
                          tile=t, mt=mt, rank=rank,
                          with_theta=with_theta),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Bi, Bj, n, m), P.dtype),
        interpret=interpret,
    )(packs_i.col, packs_i.count, packs_j.col, packs_j.count, *inputs)


def _kernel(slot_a, col_a, slot_b, col_b,   # scalar-prefetch refs
            *refs, edge_kernel, acc_dtype, fused, batched):
    """Legacy unrolled-grid kernel body (per-pair and batched).

    Grid layout: (nt, mt, ka, kb) per-pair, (B, nt, mt, ka, kb) batched;
    the two trailing dims are the reduction over octile slots, so the
    output block is revisited consecutively and accumulation is race-free.
    Kept as the benchmark baseline for the row-panel kernel above.
    """
    d = 1 if batched else 0
    kk, kkp = pl.program_id(2 + d), pl.program_id(3 + d)
    n_kk, n_kkp = pl.num_programs(2 + d), pl.num_programs(3 + d)
    if fused:
        a_ref, e_ref, ap_ref, ep_ref, p_ref, diag_ref, pe_ref, o_ref = refs
    else:
        a_ref, e_ref, ap_ref, ep_ref, p_ref, o_ref = refs
        diag_ref = pe_ref = None

    @pl.when(jnp.logical_and(kk == 0, kkp == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    if batched:
        a, e = a_ref[0, 0].astype(acc_dtype), e_ref[0, 0]
        ap, ep = ap_ref[0, 0].astype(acc_dtype), ep_ref[0, 0]
        p = p_ref[0].astype(acc_dtype)
    else:
        a, e = a_ref[0].astype(acc_dtype), e_ref[0]
        ap, ep = ap_ref[0].astype(acc_dtype), ep_ref[0]
        p = p_ref[...].astype(acc_dtype)
    contrib = _contrib(a, e, ap, ep, p, edge_kernel,
                       acc_dtype).astype(o_ref.dtype)
    if batched:
        contrib = contrib[None]

    if not fused:
        o_ref[...] += contrib
        return

    acc = o_ref[...] + contrib
    last = jnp.logical_and(kk == n_kk - 1, kkp == n_kkp - 1)

    @pl.when(last)
    def _epilogue():
        # final grid step owns the completed y block: emit diag*p - y
        o_ref[...] = (diag_ref[...] * pe_ref[...]).astype(o_ref.dtype) - acc

    @pl.when(jnp.logical_not(last))
    def _accumulate():
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("edge_kernel", "interpret",
                                             "acc_dtype"))
def xmv_block_sparse(pack1: TilePack, pack2: TilePack, P, edge_kernel, *,
                     diag=None, interpret=None, acc_dtype=jnp.float32):
    """y = (A (x) A' .* E (x)k E') P using only non-empty octiles.

    Legacy unrolled-grid kernel: every (slot, slot') pair is a full grid
    step. Superseded by :func:`xmv_row_panel`; kept as the baseline arm
    of the BENCH_xmv comparison and the parity tests.

    With ``diag`` ([n, m]) the kernel instead returns the fused CG operator
    application ``diag * P - y`` (epilogue in the last reduction step).

    Work: O(K1_max_row * K2_max_row * nt * mt * t^4) vs the dense kernel's
    O(n^2 m^2) — the paper's Fig. 9 'Sparse' rung.
    """
    t = pack1.tile
    nt, mt = pack1.n_tile_rows, pack2.n_tile_rows
    ka, kb = pack1.slot.shape[1], pack2.slot.shape[1]
    n, m = P.shape
    if n != nt * t or m != mt * t:
        raise ValueError(f"P shape {P.shape} inconsistent with tile packs"
                         f" ({nt}x{t}, {mt}x{t})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fused = diag is not None

    in_specs = [
        pl.BlockSpec((1, t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (sa[i, kk], 0, 0)),
        pl.BlockSpec((1, t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (sa[i, kk], 0, 0)),
        pl.BlockSpec((1, t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (sb[ip, kkp], 0, 0)),
        pl.BlockSpec((1, t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (sb[ip, kkp], 0, 0)),
        pl.BlockSpec((t, t),
                     lambda i, ip, kk, kkp, sa, ca, sb, cb:
                     (ca[i, kk], cb[ip, kkp])),
    ]
    inputs = [pack1.values_adj, pack1.values_lab,
              pack2.values_adj, pack2.values_lab, P]
    if fused:
        out_map = lambda i, ip, kk, kkp, sa, ca, sb, cb: (i, ip)  # noqa
        in_specs += [pl.BlockSpec((t, t), out_map),   # diag block
                     pl.BlockSpec((t, t), out_map)]   # P at the OUT block
        inputs += [diag, P]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nt, mt, ka, kb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (t, t), lambda i, ip, kk, kkp, sa, ca, sb, cb: (i, ip)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, edge_kernel=edge_kernel,
                          acc_dtype=acc_dtype, fused=fused, batched=False),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), P.dtype),
        interpret=interpret,
    )(pack1.slot, pack1.col, pack2.slot, pack2.col, *inputs)
    return out


@functools.partial(jax.jit, static_argnames=("edge_kernel", "interpret",
                                             "acc_dtype"))
def xmv_block_sparse_batched(packs1: TilePack, packs2: TilePack, P,
                             edge_kernel, *, diag=None, interpret=None,
                             acc_dtype=jnp.float32):
    """Whole-bucket block-sparse XMV in ONE ``pallas_call`` (legacy grid).

    ``packs1``/``packs2`` are stacked TilePacks (``ops.stack_packs``) with a
    leading [B] axis on every field; ``P`` is [B, n, m]. The pair axis is
    the outermost grid dimension and the scalar-prefetch index maps select
    per-pair tiles via ``slot[b, i, k]`` — replacing B dispatches (and B
    jit boundaries) per CG iteration with one (paper Sec. V). Every
    (slot, slot') pair is still a separate grid step that re-fetches its
    octiles; :func:`xmv_row_panel_batched` removes that too. Kept as the
    benchmark baseline.

    With ``diag`` ([B, n, m]) the fused epilogue emits ``diag * P - y``.
    """
    B = packs1.values_adj.shape[0]
    t = packs1.values_adj.shape[-1]
    nt, mt = packs1.slot.shape[1], packs2.slot.shape[1]
    ka, kb = packs1.slot.shape[2], packs2.slot.shape[2]
    Bp, n, m = P.shape
    if Bp != B:
        raise ValueError(f"P batch {Bp} != pack batch {B}")
    if n != nt * t or m != mt * t:
        raise ValueError(f"P shape {P.shape} inconsistent with tile packs"
                         f" ({nt}x{t}, {mt}x{t})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fused = diag is not None

    in_specs = [
        pl.BlockSpec((1, 1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, sa[b, i, kk], 0, 0)),
        pl.BlockSpec((1, 1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, sa[b, i, kk], 0, 0)),
        pl.BlockSpec((1, 1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, sb[b, ip, kkp], 0, 0)),
        pl.BlockSpec((1, 1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, sb[b, ip, kkp], 0, 0)),
        pl.BlockSpec((1, t, t),
                     lambda b, i, ip, kk, kkp, sa, ca, sb, cb:
                     (b, ca[b, i, kk], cb[b, ip, kkp])),
    ]
    inputs = [packs1.values_adj, packs1.values_lab,
              packs2.values_adj, packs2.values_lab, P]
    if fused:
        out_map = lambda b, i, ip, kk, kkp, sa, ca, sb, cb: (b, i, ip)  # noqa
        in_specs += [pl.BlockSpec((1, t, t), out_map),
                     pl.BlockSpec((1, t, t), out_map)]
        inputs += [diag, P]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, nt, mt, ka, kb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, t, t), lambda b, i, ip, kk, kkp, sa, ca, sb, cb: (b, i, ip)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, edge_kernel=edge_kernel,
                          acc_dtype=acc_dtype, fused=fused, batched=True),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n, m), P.dtype),
        interpret=interpret,
    )(packs1.slot, packs1.col, packs2.slot, packs2.col, *inputs)
    return out
