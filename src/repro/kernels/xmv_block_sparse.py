"""Block-sparse on-the-fly Kronecker XMV over non-empty octiles.

The TPU port of the paper's inter-tile sparsity exploitation (Sec. IV-A):
only non-empty octiles participate. The CUDA kernel streams a COO tile list
per warp and resolves output collisions with atomics; TPUs have neither
warps nor atomics, so (DESIGN.md §2):

* the COO list is re-bucketed BY TILE ROW at preprocessing time
  (``pack_octiles``), padded to the max tiles-per-row with pointers to a
  designated all-zero tile — zero contributions instead of control flow;
* the grid iterates (tile_row_i, tile_row_i', slot, slot'); the output
  block (i, i') is constant over the two inner reduction dims, so
  accumulation is race-free by construction (no atomics needed);
* the *dynamic* tile indirection uses scalar prefetch
  (PrefetchScalarGridSpec): the slot/column index arrays are prefetched to
  SMEM and drive the BlockSpec index_maps — the TPU-idiomatic equivalent of
  the warp reading COO coordinates.

Intra-tile sparsity (Sec. IV-B, bitmap compaction) lives at the storage
level: HBM holds only packed non-empty tiles; the kernel computes on dense
t x t blocks after VMEM expansion, mirroring the paper's "stored compact,
expanded in shared memory".
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.octile import OctileSet, octile_decompose

__all__ = ["TilePack", "pack_octiles", "xmv_block_sparse"]


class TilePack(NamedTuple):
    """Device-side row-bucketed octile storage for one graph.

    values_adj/values_lab: [K+1, t, t] packed non-empty tiles; slot K is
      all-zero (the padding target).
    slot: [n_tile_rows, k_max] int32 -> index into values_*.
    col:  [n_tile_rows, k_max] int32 tile-column (P block index).
    """
    values_adj: jnp.ndarray
    values_lab: jnp.ndarray
    slot: jnp.ndarray
    col: jnp.ndarray

    @property
    def tile(self) -> int:
        return self.values_adj.shape[-1]

    @property
    def n_tile_rows(self) -> int:
        return self.slot.shape[0]


def pack_octiles(oset: OctileSet, k_max: int | None = None) -> TilePack:
    """Host-side: bucket an OctileSet's COO list by tile row."""
    t, nt = oset.tile, oset.n_tiles_side
    K_total = oset.coords.shape[0]       # includes padded() slots, if any
    real = oset.coords[:, 0] >= 0        # padded() marks pad slots with -1
    K = int(real.sum())
    rows = oset.coords[:K, 0]
    counts = np.bincount(rows, minlength=nt) if K else np.zeros(nt, np.int64)
    if k_max is None:
        k_max = max(int(counts.max(initial=0)), 1)
    elif counts.max(initial=0) > k_max:
        raise ValueError(f"k_max={k_max} < max tiles per row {counts.max()}")
    slot = np.full((nt, k_max), K_total, np.int32)   # K_total = zero tile
    col = np.zeros((nt, k_max), np.int32)
    fill = np.zeros(nt, np.int64)
    for k in range(K):
        r, c = oset.coords[k]
        slot[r, fill[r]] = k
        col[r, fill[r]] = c
        fill[r] += 1
    vals_a = np.concatenate(
        [oset.values_adj, np.zeros((1, t, t), np.float32)], axis=0)
    vals_e = np.concatenate(
        [oset.values_lab, np.zeros((1, t, t), np.float32)], axis=0)
    return TilePack(values_adj=jnp.asarray(vals_a),
                    values_lab=jnp.asarray(vals_e),
                    slot=jnp.asarray(slot), col=jnp.asarray(col))


def pack_graph(adjacency, edge_labels=None, tile: int = 8,
               k_max: int | None = None) -> TilePack:
    """Convenience: dense matrix -> TilePack."""
    return pack_octiles(octile_decompose(np.asarray(adjacency),
                                         None if edge_labels is None
                                         else np.asarray(edge_labels),
                                         tile=tile), k_max=k_max)


def _kernel(slot_a, col_a, slot_b, col_b,   # scalar-prefetch refs
            a_ref, e_ref, ap_ref, ep_ref, p_ref, o_ref, *,
            edge_kernel, acc_dtype):
    kk, kkp = pl.program_id(2), pl.program_id(3)

    @pl.when(jnp.logical_and(kk == 0, kkp == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0].astype(acc_dtype)     # [t, t]
    e = e_ref[0]
    ap = ap_ref[0].astype(acc_dtype)   # [t, t]
    ep = ep_ref[0]
    p = p_ref[...].astype(acc_dtype)   # [t, t]
    kappa = edge_kernel(e[:, :, None, None],
                        ep[None, None, :, :]).astype(acc_dtype)
    w = a[:, :, None, None] * ap[None, None, :, :] * kappa
    o_ref[...] += jnp.sum(w * p[None, :, None, :],
                          axis=(1, 3)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("edge_kernel", "interpret",
                                             "acc_dtype"))
def xmv_block_sparse(pack1: TilePack, pack2: TilePack, P, edge_kernel, *,
                     interpret=None, acc_dtype=jnp.float32):
    """y = (A (x) A' .* E (x)k E') P using only non-empty octiles.

    Work: O(K1_max_row * K2_max_row * nt * mt * t^4) vs the dense kernel's
    O(n^2 m^2) — the paper's Fig. 9 'Sparse' rung.
    """
    t = pack1.tile
    nt, mt = pack1.n_tile_rows, pack2.n_tile_rows
    ka, kb = pack1.slot.shape[1], pack2.slot.shape[1]
    n, m = P.shape
    if n != nt * t or m != mt * t:
        raise ValueError(f"P shape {P.shape} inconsistent with tile packs"
                         f" ({nt}x{t}, {mt}x{t})")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nt, mt, ka, kb),
        in_specs=[
            pl.BlockSpec((1, t, t),
                         lambda i, ip, kk, kkp, sa, ca, sb, cb:
                         (sa[i, kk], 0, 0)),
            pl.BlockSpec((1, t, t),
                         lambda i, ip, kk, kkp, sa, ca, sb, cb:
                         (sa[i, kk], 0, 0)),
            pl.BlockSpec((1, t, t),
                         lambda i, ip, kk, kkp, sa, ca, sb, cb:
                         (sb[ip, kkp], 0, 0)),
            pl.BlockSpec((1, t, t),
                         lambda i, ip, kk, kkp, sa, ca, sb, cb:
                         (sb[ip, kkp], 0, 0)),
            pl.BlockSpec((t, t),
                         lambda i, ip, kk, kkp, sa, ca, sb, cb:
                         (ca[i, kk], cb[ip, kkp])),
        ],
        out_specs=pl.BlockSpec(
            (t, t), lambda i, ip, kk, kkp, sa, ca, sb, cb: (i, ip)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, edge_kernel=edge_kernel,
                          acc_dtype=acc_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), P.dtype),
        interpret=interpret,
    )(pack1.slot, pack1.col, pack2.slot, pack2.col,
      pack1.values_adj, pack1.values_lab,
      pack2.values_adj, pack2.values_lab, P)
    return out
