"""Pure-jnp oracles for every Pallas kernel in this package.

Each Pallas kernel's tests sweep shapes and dtypes and assert_allclose
against these references (interpret=True on CPU)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["xmv_ref", "xmv_batched_ref", "attention_ref"]


def xmv_ref(A, E, Ap, Ep, P, edge_kernel):
    """y[i,k] = sum_{j,l} A[i,j] Ap[k,l] kappa(E[i,j], Ep[k,l]) P[j,l].

    Full O(n^2 m^2) materialization — ground truth for the on-the-fly
    kernels (identical to core.xmv.xmv_full, re-exported here so the
    kernels package is self-contained)."""
    K = edge_kernel(E[:, :, None, None], Ep[None, None, :, :])
    W = A[:, :, None, None] * Ap[None, None, :, :] * K
    return jnp.einsum("ijkl,jl->ik", W, P)


def xmv_batched_ref(A, E, Ap, Ep, P, edge_kernel):
    import jax
    return jax.vmap(lambda a, e, ap, ep, p:
                    xmv_ref(a, e, ap, ep, p, edge_kernel))(A, E, Ap, Ep, P)


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None,
                  window: int | None = None):
    """Plain softmax attention oracle: q,k,v [B, H, S, D] -> [B, H, S, D]."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    s = q.shape[-2]
    pos_q = jnp.arange(s)[:, None]
    pos_k = jnp.arange(k.shape[-2])[None, :]
    mask = jnp.ones((s, k.shape[-2]), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v)
