"""Tiled online-softmax (flash) attention for the LM zoo.

DESIGN.md §5: this kernel exists because the paper's central idea — stream
operand tiles and regenerate a bandwidth-heavy product on the fly instead of
materializing it in HBM — is exactly the flash-attention trick. The tiling
structure mirrors kernels/xmv_dense.py: grid (batch, head, q_block,
kv_block) with the kv_block reduction innermost, VMEM scratch accumulators,
and masking instead of divergent control flow.

Supports causal masking, sliding windows (gemma3 local layers) and GQA
(kv head indexing by query-head group). Validated against
kernels/ref.py:attention_ref in interpret mode; the LM models select it via
``attention_impl="pallas"`` (default "reference" so CPU dry-runs lower
without TPU-only ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, blk_q, blk_k, n_kv_blocks):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)      # [blk_q, d]
    k = k_ref[0, 0].astype(jnp.float32)      # [blk_k, d]
    v = v_ref[0, 0].astype(jnp.float32)      # [blk_k, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    pos_q = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 0)
    pos_k = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 1)
    mask = jnp.ones((blk_q, blk_k), jnp.bool_)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                      # [blk_q, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)           # [blk_q, 1]
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "blk_q", "blk_k",
                     "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, blk_q: int = 128,
                    blk_k: int = 128, interpret: bool | None = None):
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] with Hq % Hkv == 0."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    if S % blk_q or S % blk_k:
        raise ValueError(f"S={S} must be divisible by blocks {blk_q},{blk_k}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_kv_blocks = S // blk_k
    grid = (B, Hq, S // blk_q, n_kv_blocks)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          blk_q=blk_q, blk_k=blk_k,
                          n_kv_blocks=n_kv_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, blk_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
