"""Jit'd dispatch wrappers over the Pallas kernels.

Every entry point auto-selects interpret mode off-TPU so the same call
sites run on CPU (tests, this container) and TPU (production) unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .ref import attention_ref, xmv_batched_ref, xmv_ref
from .xmv_block_sparse import RowPanelPack, TilePack, \
    device_weighted_pack, pack_graph, pack_graph_row_panels, \
    pack_octiles, pack_row_panels, xmv_block_sparse, \
    xmv_block_sparse_batched, xmv_gram_tile, xmv_row_panel, \
    xmv_row_panel_batched
from .xmv_dense import pick_tiles, xmv_dense, xmv_dense_batched

__all__ = [
    "xmv_dense", "xmv_dense_batched", "xmv_block_sparse",
    "xmv_block_sparse_batched", "xmv_block_sparse_unrolled", "stack_packs",
    "pack_graph", "pack_octiles", "TilePack", "RowPanelPack",
    "pack_row_panels", "pack_graph_row_panels", "xmv_row_panel",
    "xmv_row_panel_batched", "xmv_gram_tile", "stack_row_panel_packs",
    "device_weighted_pack", "take_row_panel_pack",
    "row_panel_packs_for_batch", "flash_attention",
    "attention_ref", "xmv_ref", "xmv_batched_ref", "pick_tiles",
]


def _stack_field(packs, field):
    """Stack one optional pack field: all-None -> None, else jnp.stack."""
    vals = [getattr(p, field) for p in packs]
    if any(v is None for v in vals):
        if not all(v is None for v in vals):
            raise ValueError(
                f"cannot stack packs mixing {field} and None")
        return None
    return jnp.stack(vals)


def stack_packs(packs: list[TilePack]) -> TilePack:
    """Stack per-pair TilePacks (same bucket => same shapes) to [B, ...];
    optional fields (``values_grad``) must be present in all or none."""
    return TilePack(*(_stack_field(packs, f) for f in TilePack._fields))


def take_row_panel_pack(pack: RowPanelPack, indices) -> RowPanelPack:
    """Gather a stacked RowPanelPack along its leading pair/graph axis
    (``indices`` int array) — the segmented-PCG pair-retirement remap
    and the Gram-tile -> per-pair pack expansion (core/mgk.py)."""
    idx = jnp.asarray(indices)
    return RowPanelPack(*(None if f is None else jnp.take(f, idx, axis=0)
                          for f in pack))


def stack_row_panel_packs(packs: list[RowPanelPack]) -> RowPanelPack:
    """Stack per-pair RowPanelPacks (same bucket => same shapes) to
    [B, ...]; optional fields (``values_w``/``values_grad``) must be
    present in all packs or in none."""
    return RowPanelPack(*(_stack_field(packs, f)
                          for f in RowPanelPack._fields))


def _bucket_osets(batch, tile: int):
    import numpy as np
    from repro.core.octile import octile_decompose
    n = batch.adjacency.shape[1]
    if n % tile:
        raise ValueError(
            f"batch padded to {n}, not a multiple of tile={tile}; pad the"
            f" bucket to a multiple of the tile edge")
    B = batch.adjacency.shape[0]
    return [octile_decompose(np.asarray(batch.adjacency[b]),
                             np.asarray(batch.edge_labels[b]), tile=tile)
            for b in range(B)]


def packs_for_batch(batch, tile: int = 8) -> TilePack:
    """Host-side: octile-decompose every graph of a GraphBatch and stack
    the legacy TilePacks to shared shapes (pads tile counts to the bucket
    max)."""
    import numpy as np
    osets = _bucket_osets(batch, tile)
    K = max(max(o.n_nonempty for o in osets), 1)
    k_max = max(max((np.bincount(o.coords[:, 0]).max(initial=0)
                     if o.n_nonempty else 0) for o in osets), 1)
    return stack_packs([pack_octiles(o.padded(K), k_max=int(k_max))
                        for o in osets])


def row_panel_packs_for_batch(batch, tile: int = 8, edge_kernel=None,
                              with_grad: bool = False,
                              pack_dtype=None) -> RowPanelPack:
    """Host-side: octile-decompose every graph of a GraphBatch into
    row-panel packs stacked to shared shapes (slot counts padded to the
    bucket max). Pass ``edge_kernel`` with a feature expansion to also
    precompute the MXU contraction operands (``values_w``);
    ``with_grad`` adds the ``values_grad`` adjoint companions.
    ``pack_dtype=jnp.bfloat16`` streams the value buffers at half the
    HBM bytes per matvec (f32 in-kernel accumulation, DESIGN.md §9.4)."""
    import numpy as np
    osets = _bucket_osets(batch, tile)
    k_max = max(max((np.bincount(o.coords[:, 0]).max(initial=0)
                     if o.n_nonempty else 0) for o in osets), 1)
    return stack_row_panel_packs(
        [pack_row_panels(o, edge_kernel=edge_kernel, k_max=int(k_max),
                         with_grad=with_grad, pack_dtype=pack_dtype)
         for o in osets])


def xmv_block_sparse_unrolled(packs1: TilePack, packs2: TilePack, P,
                              edge_kernel, *, diag=None, **kw):
    """Legacy loop-of-launches batched block-sparse XMV: one ``pallas_call``
    (and one jit dispatch) per pair. Superseded by the batched-grid
    :func:`~repro.kernels.xmv_block_sparse.xmv_block_sparse_batched`
    (one launch for the whole bucket); kept as the baseline arm of the
    BENCH_xmv comparison and the parity tests."""
    B = P.shape[0]

    def take(pack, b):
        return TilePack(*(None if arr is None else arr[b] for arr in pack))

    ys = [
        xmv_block_sparse(
            take(packs1, b), take(packs2, b),
            P[b], edge_kernel,
            diag=None if diag is None else diag[b], **kw)
        for b in range(B)
    ]
    return jnp.stack(ys)


def attention_chunked(q, k, v, *, causal: bool = True,
                      window: int | None = None, scale: float | None = None,
                      blk_q: int = 512, blk_k: int = 512):
    """Flash-attention algorithm in pure jnp: scan over query blocks, inner
    scan over KV blocks with online-softmax accumulation. Never
    materializes the S x S score matrix in HBM — the paper's on-the-fly
    regeneration insight applied to attention (DESIGN.md §5). This is the
    §Perf 'attention=chunked' variant; HBM traffic scales as
    O(S*D*(2 + S/blk_q)) instead of O(S^2).
    """
    B, Hq, S, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    def _fit(dim, blk):
        blk = min(blk, dim)
        while dim % blk:
            blk -= 1
        return blk
    blk_q = _fit(S, blk_q)
    blk_k = _fit(Sk, blk_k)
    qg = q.reshape(B, Hkv, rep, S, D)
    # [nq, B, G, R, blk_q, D] / [nk, B, G, blk_k, D]
    qs = jnp.moveaxis(qg.reshape(B, Hkv, rep, S // blk_q, blk_q, D), 3, 0)
    ks = jnp.moveaxis(k.reshape(B, Hkv, Sk // blk_k, blk_k, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, Hkv, Sk // blk_k, blk_k, D), 2, 0)

    def q_block(_, inp):
        qi, qblk = inp                                # [], [B,G,R,blk_q,D]
        q0 = qi * blk_q

        def kv_block(carry, kin):
            acc, m, l = carry
            ki, kblk, vblk = kin
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qblk, kblk) * scale
            pos_q = q0 + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 0)
            pos_k = ki * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            mask = jnp.ones((blk_q, blk_k), bool)
            if causal:
                mask &= pos_k <= pos_q
            if window is not None:
                mask &= pos_k > pos_q - window
            s = jnp.where(mask, s.astype(jnp.float32), -1e30)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1, keepdims=True)
            acc = acc * corr + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros(qblk.shape[:4] + (D,), jnp.float32)
        m0 = jnp.full(qblk.shape[:4] + (1,), -1e30, jnp.float32)
        l0 = jnp.zeros(qblk.shape[:4] + (1,), jnp.float32)
        nk = Sk // blk_k
        (acc, _, l), _ = jax.lax.scan(
            kv_block, (acc0, m0, l0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)
        return None, out.astype(q.dtype)

    nq = S // blk_q
    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    # outs: [nq, B, G, R, blk_q, D] -> [B, Hq, S, D]
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Hkv, rep, S, D)
    return out.reshape(B, Hq, S, D)


def attention(q, k, v, *, impl: str = "reference", causal: bool = True,
              window: int | None = None, scale: float | None = None):
    """Attention dispatch used by the LM zoo layers."""
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale)
    if impl == "chunked":
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 scale=scale)
    if impl == "reference":
        # GQA-native grouped einsums (no kv repeat materialization)
        B, Hq, S, D = q.shape
        Hkv, Sk = k.shape[1], k.shape[2]
        rep = Hq // Hkv
        if scale is None:
            scale = D ** -0.5
        qg = q.reshape(B, Hkv, rep, S, D)
        logits = jnp.einsum("bgrqd,bgkd->bgrqk", qg, k) * scale
        pos_q = jnp.arange(S)[:, None]
        pos_k = jnp.arange(Sk)[None, :]
        mask = jnp.ones((S, Sk), bool)
        if causal:
            mask &= pos_k <= pos_q
        if window is not None:
            mask &= pos_k > pos_q - window
        logits = jnp.where(mask, logits.astype(jnp.float32), -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bgrqk,bgkd->bgrqd", w, v)
        return out.reshape(B, Hq, S, D)
    raise ValueError(f"unknown attention impl {impl!r}")
