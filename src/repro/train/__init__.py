"""Training substrate: optimizers (AdamW / 8-bit AdamW / Adafactor),
loss, train/serve step builders with remat + grad accumulation."""
from .optimizer import OptState, make_optimizer
from .steps import make_train_step, make_prefill_step, make_decode_step, \
    loss_fn

__all__ = ["OptState", "make_optimizer", "make_train_step",
           "make_prefill_step", "make_decode_step", "loss_fn"]
