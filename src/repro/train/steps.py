"""Step builders: train (loss + grad + optimizer), prefill, decode.

These are what the launcher jits with the mesh shardings and what the
dry-run lowers for every (arch x shape) cell.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, forward, mtp_logits
from .optimizer import make_optimizer

__all__ = ["loss_fn", "make_train_step", "make_prefill_step",
           "make_decode_step"]

AUX_LOSS_COEF = 0.01
MTP_LOSS_COEF = 0.3


def _xent(logits, labels, vocab_real: int):
    """Cross entropy with masking of the padded vocab tail."""
    logits = logits.astype(jnp.float32)
    # mask padded vocab entries so they never win
    v = logits.shape[-1]
    if v > vocab_real:
        neg = jnp.full((v - vocab_real,), -1e30, logits.dtype)
        logits = logits + jnp.concatenate(
            [jnp.zeros((vocab_real,), logits.dtype), neg])
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return logz - gold


def loss_fn(cfg: ModelConfig, params, batch: dict):
    """Next-token LM loss (+ MoE aux + MTP head when configured)."""
    tokens = batch["tokens"]
    logits, _, aux, hidden = forward(cfg, params, batch, training=True,
                                     return_hidden=True)
    nll = _xent(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)
    loss = nll.mean()
    metrics = {"nll": loss, "aux": aux}
    total = loss + AUX_LOSS_COEF * aux
    if cfg.mtp_heads:
        # DeepSeek MTP: predict token t+2 from final hidden_t combined
        # with the embedding of token t+1
        emb_next = jnp.take(params["embed"], tokens[:, 1:], axis=0)
        mlog = mtp_logits(cfg, params, hidden[:, :-2], emb_next[:, :-1])
        mtp_nll = _xent(mlog, tokens[:, 2:], cfg.vocab_size).mean()
        metrics["mtp_nll"] = mtp_nll
        total = total + MTP_LOSS_COEF * mtp_nll
    metrics["loss"] = total
    return total, metrics


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    accum_steps: int = 1,
                    warmup_steps: int = 0) -> tuple[Callable, Callable]:
    """Returns (init_state_fn(params)->opt_state, step_fn).

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics).
    ``accum_steps`` > 1 splits the batch into microbatches and accumulates
    gradients with a scan (activation memory / global batch decoupling).
    """
    opt_init, opt_update = make_optimizer(cfg.optimizer, lr=lr,
                                          warmup_steps=warmup_steps)
    grad_fn = jax.grad(functools.partial(loss_fn, cfg), has_aux=True)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            grads, metrics = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                g_acc = carry
                g, m = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return g_acc, m

            micro_batch = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(micro, zeros, micro_batch)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        new_params, new_opt = opt_update(grads, opt_state, params)
        return new_params, new_opt, metrics

    return opt_init, step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """prefill(params, batch, cache) -> (last_logits, filled cache)."""
    from repro.models.model import init_cache

    def prefill(params, batch, cache):
        logits, new_cache, _ = forward(cfg, params, batch, cache=cache)
        return logits[:, -1:], new_cache

    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """decode(params, cache, token[B,1]) -> (logits[B,1,V], cache)."""

    def decode(params, cache, token):
        return decode_step(cfg, params, cache, token)

    return decode
