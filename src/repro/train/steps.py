"""Step builders: train (loss + grad + optimizer), prefill, decode —
plus the graph-kernel learning steps (GP hyperparameter optimization on
the differentiable MGK, DESIGN.md §7).

The LM builders are what the launcher jits with the mesh shardings and
what the dry-run lowers for every (arch x shape) cell. The GP builders
are what examples/gp_fit.py drives: the loss is the GP negative log
marginal likelihood of a bucketed graph dataset, whose gradient flows
through the adjoint-PCG custom VJP of core/adjoint.py — cholesky and
Gram assembly differentiate natively, only the solve needed a custom
rule.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, forward, mtp_logits
from .optimizer import make_optimizer

__all__ = ["loss_fn", "make_train_step", "make_prefill_step",
           "make_decode_step", "make_gp_nlml", "make_gp_step",
           "DEFAULT_THETA_BOUNDS"]

AUX_LOSS_COEF = 0.01
MTP_LOSS_COEF = 0.3


def _xent(logits, labels, vocab_real: int):
    """Cross entropy with masking of the padded vocab tail."""
    logits = logits.astype(jnp.float32)
    # mask padded vocab entries so they never win
    v = logits.shape[-1]
    if v > vocab_real:
        neg = jnp.full((v - vocab_real,), -1e30, logits.dtype)
        logits = logits + jnp.concatenate(
            [jnp.zeros((vocab_real,), logits.dtype), neg])
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return logz - gold


def loss_fn(cfg: ModelConfig, params, batch: dict):
    """Next-token LM loss (+ MoE aux + MTP head when configured)."""
    tokens = batch["tokens"]
    logits, _, aux, hidden = forward(cfg, params, batch, training=True,
                                     return_hidden=True)
    nll = _xent(logits[:, :-1], tokens[:, 1:], cfg.vocab_size)
    loss = nll.mean()
    metrics = {"nll": loss, "aux": aux}
    total = loss + AUX_LOSS_COEF * aux
    if cfg.mtp_heads:
        # DeepSeek MTP: predict token t+2 from final hidden_t combined
        # with the embedding of token t+1
        emb_next = jnp.take(params["embed"], tokens[:, 1:], axis=0)
        mlog = mtp_logits(cfg, params, hidden[:, :-2], emb_next[:, :-1])
        mtp_nll = _xent(mlog, tokens[:, 2:], cfg.vocab_size).mean()
        metrics["mtp_nll"] = mtp_nll
        total = total + MTP_LOSS_COEF * mtp_nll
    metrics["loss"] = total
    return total, metrics


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    accum_steps: int = 1,
                    warmup_steps: int = 0) -> tuple[Callable, Callable]:
    """Returns (init_state_fn(params)->opt_state, step_fn).

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics).
    ``accum_steps`` > 1 splits the batch into microbatches and accumulates
    gradients with a scan (activation memory / global batch decoupling).
    """
    opt_init, opt_update = make_optimizer(cfg.optimizer, lr=lr,
                                          warmup_steps=warmup_steps)
    grad_fn = jax.grad(functools.partial(loss_fn, cfg), has_aux=True)

    def step(params, opt_state, batch):
        if accum_steps == 1:
            grads, metrics = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                g_acc = carry
                g, m = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return g_acc, m

            micro_batch = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(micro, zeros, micro_batch)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        new_params, new_opt = opt_update(grads, opt_state, params)
        return new_params, new_opt, metrics

    return opt_init, step


# -- graph-kernel learning steps (differentiable MGK, DESIGN.md §7) -------

# hyperparameters live in constrained domains (kappa must stay PD with
# range in (0, 1]; q is a probability); plain gradient steps are
# projected back in after each update
DEFAULT_THETA_BOUNDS = {
    "vertex.h": (1e-3, 0.999),
    "edge.h": (1e-3, 0.999),
    "edge.alpha": (1e-2, 50.0),
    "vertex.alpha": (1e-2, 50.0),
    "edge.support": (1e-2, 10.0),
    "vertex.support": (1e-2, 10.0),
    "edge.value": (1e-3, 1.0),
    "vertex.value": (1e-3, 1.0),
    "q": (1e-3, 0.9),
}


def _clip_theta(theta: dict, bounds: dict) -> dict:
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}.{k}" if prefix else k, v)
                    for k, v in node.items()}
        lohi = bounds.get(prefix)
        if lohi is None:
            return node
        return jnp.clip(node, lohi[0], lohi[1])

    return walk("", theta)


def make_gp_nlml(ds, y, vertex_kernel, edge_kernel, *,
                 method: str = "lowrank", noise: float = 1e-4,
                 tol: float = 1e-10, max_iter: int = 512,
                 fixed_iters: int | None = None,
                 pcg_variant: str = "classic",
                 precond: str = "jacobi",
                 kron_rank: int = 2) -> Callable:
    """Build ``nlml(theta) -> scalar`` over a BucketedDataset.

    All (i <= j) pairs are grouped by (bucket_i, bucket_j) into aligned
    pair batches — each group gets ONE adjoint-differentiable value
    function (core/adjoint.py), built once and reused across every
    optimization step — and the assembled Gram feeds the standard GP
    negative log marginal likelihood

        NLML = y^T (K + σ²I)^{-1} y / 2 + log det(K + σ²I) / 2 + const.

    ``theta`` is the :func:`repro.core.adjoint.kernel_theta` pytree;
    gradients w.r.t. every hyperparameter (q included) flow through
    cholesky/assembly natively and through each MGK solve via its
    custom VJP — two PCG solves per pair batch per step, regardless of
    the number of hyperparameters. ``precond="kron"`` runs both solves
    with the Kronecker-factored preconditioner (DESIGN.md §9) — per
    optimization step the hyperparameters move but the factors (pure
    graph statistics) don't, so they are built once per group here.
    """
    from repro.core.adjoint import mgk_value_fn
    N = len(ds)
    y = jnp.asarray(y, jnp.float32)
    iu, ju = np.triu_indices(N)
    groups: dict[tuple[int, int], list[int]] = {}
    for k in range(len(iu)):
        key = (ds.bucket_of(int(iu[k])), ds.bucket_of(int(ju[k])))
        groups.setdefault(key, []).append(k)
    fns = []
    for (bi, bj), ks in groups.items():
        rows = [int(iu[k]) for k in ks]
        cols = [int(ju[k]) for k in ks]
        g1 = ds.batch(rows, pad_to=ds.buckets[bi].pad_to)
        g2 = ds.batch(cols, pad_to=ds.buckets[bj].pad_to)
        fn = mgk_value_fn(g1, g2, vertex_kernel, edge_kernel,
                          method=method, tol=tol, max_iter=max_iter,
                          fixed_iters=fixed_iters,
                          pcg_variant=pcg_variant, precond=precond,
                          kron_rank=kron_rank)
        fns.append((np.array(rows), np.array(cols), fn))

    def nlml(theta):
        K = jnp.zeros((N, N), jnp.float32)
        for rows, cols, fn in fns:
            vals = fn(theta)
            K = K.at[rows, cols].set(vals)
        # values land on the upper triangle (rows <= cols); mirror it
        K = jnp.triu(K) + jnp.triu(K, 1).T
        Kn = K + noise * jnp.eye(N, dtype=K.dtype)
        L = jnp.linalg.cholesky(Kn)
        alpha = jax.scipy.linalg.cho_solve((L, True), y)
        return (0.5 * jnp.dot(y, alpha)
                + jnp.sum(jnp.log(jnp.diag(L)))
                + 0.5 * N * jnp.log(2.0 * jnp.pi))

    return nlml


def make_gp_step(nlml: Callable, *, optimizer: str = "adamw",
                 lr: float = 5e-2, bounds: dict | None = None
                 ) -> tuple[Callable, Callable]:
    """Returns (init_fn(theta) -> opt_state, step_fn) for GP
    hyperparameter optimization:

        step_fn(theta, opt_state) -> (theta', opt_state', loss)

    Each step is loss + gradient (via the adjoint custom VJP inside
    ``nlml``) + one optimizer update, with the result projected into
    ``bounds`` (:data:`DEFAULT_THETA_BOUNDS` keyed by flat theta path)
    to keep the base kernels positive definite."""
    bounds = DEFAULT_THETA_BOUNDS if bounds is None else bounds
    opt_init, opt_update = make_optimizer(optimizer, lr=lr,
                                          weight_decay=0.0)
    vg = jax.value_and_grad(nlml)

    def init(theta):
        theta = jax.tree.map(lambda v: jnp.asarray(v, jnp.float32), theta)
        return theta, opt_init(theta)

    def step(theta, opt_state):
        loss, grads = vg(theta)
        theta, opt_state = opt_update(grads, opt_state, theta)
        return _clip_theta(theta, bounds), opt_state, loss

    return init, step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """prefill(params, batch, cache) -> (last_logits, filled cache)."""
    from repro.models.model import init_cache

    def prefill(params, batch, cache):
        logits, new_cache, _ = forward(cfg, params, batch, cache=cache)
        return logits[:, -1:], new_cache

    return prefill


def make_decode_step(cfg: ModelConfig) -> Callable:
    """decode(params, cache, token[B,1]) -> (logits[B,1,V], cache)."""

    def decode(params, cache, token):
        return decode_step(cfg, params, cache, token)

    return decode
