"""Optimizers, optax-free, with distributed-memory tricks built in:

* ``adamw``      — fp32 moments (baseline).
* ``adamw8bit``  — int8-quantized moments with per-tensor-row absmax
                   scales: 4x less optimizer HBM and 4x less ZeRO-1
                   all-gather traffic (the "gradient/state compression"
                   knob for 1000+-node runs).
* ``adafactor``  — factored second moment (row+col statistics) for >=2D
                   tensors: O(n+m) state instead of O(nm); the default for
                   the 671B config where even sharded Adam does not fit.

Optimizer state inherits the parameter sharding (ZeRO-1 when cfg.fsdp
shards params over "data"). Global-norm clipping included.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptState", "make_optimizer"]


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any        # first moment  (pytree or quantized pytree)
    v: Any        # second moment (pytree / factored / quantized)


class _Quant(NamedTuple):
    q: jnp.ndarray        # int8 payload
    scale: jnp.ndarray    # per-row absmax scale (f32)


def _quantize(x: jnp.ndarray) -> _Quant:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return _Quant(q=q, scale=scale.astype(jnp.float32))


def _dequantize(qt: _Quant) -> jnp.ndarray:
    return qt.q.astype(jnp.float32) * qt.scale


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def make_optimizer(kind: str = "adamw", *, lr: float = 3e-4,
                   b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                   weight_decay: float = 0.1, clip_norm: float = 1.0,
                   warmup_steps: int = 0):
    """Returns (init_fn(params) -> OptState,
                update_fn(grads, state, params) -> (new_params, new_state)).

    ``warmup_steps`` linearly ramps the learning rate from 0 (standard
    transformer warmup; prevents the early-step divergence observed in
    the 100M example run)."""

    def init(params) -> OptState:
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
        if kind == "adamw":
            return OptState(jnp.zeros((), jnp.int32),
                            jax.tree.map(zeros, params),
                            jax.tree.map(zeros, params))
        if kind == "adamw8bit":
            qz = lambda p: _quantize(jnp.zeros_like(p, jnp.float32))  # noqa
            return OptState(jnp.zeros((), jnp.int32),
                            jax.tree.map(qz, params),
                            jax.tree.map(qz, params))
        if kind == "adafactor":
            def vz(p):
                if p.ndim >= 2:
                    return (jnp.zeros(p.shape[:-1], jnp.float32),
                            jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                      jnp.float32))
                return jnp.zeros_like(p, jnp.float32)
            m = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.bfloat16),
                             params)
            return OptState(jnp.zeros((), jnp.int32), m,
                            jax.tree.map(vz, params,
                                         is_leaf=lambda x: hasattr(x, "ndim")))
        raise ValueError(f"unknown optimizer {kind!r}")

    def update(grads, state: OptState, params):
        step = state.step + 1
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step.astype(jnp.float32)
                           / max(warmup_steps, 1))
        lr_t = lr * warm

        if kind in ("adamw", "adamw8bit"):
            get = _dequantize if kind == "adamw8bit" else (lambda x: x)
            put = _quantize if kind == "adamw8bit" else (lambda x: x)

            def upd(p, g, m, v):
                mf = get(m) * b1 + g * (1 - b1)
                vf = get(v) * b2 + g * g * (1 - b2)
                u = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
                u = u + weight_decay * p.astype(jnp.float32)
                new_p = (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
                return new_p, put(mf), put(vf)

            leaves_p, tdef = jax.tree_util.tree_flatten(params)
            leaves_g = tdef.flatten_up_to(grads)
            leaves_m = tdef.flatten_up_to(state.m)
            leaves_v = tdef.flatten_up_to(state.v)
            outs = [upd(p, g, m, v) for p, g, m, v in
                    zip(leaves_p, leaves_g, leaves_m, leaves_v)]
            new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
            new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
            new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])
            return new_p, OptState(step, new_m, new_v)

        if kind == "adafactor":
            def upd(p, g, m, v):
                if p.ndim >= 2:
                    vr, vc = v
                    vr = vr * b2 + jnp.mean(g * g, axis=-1) * (1 - b2)
                    vc = vc * b2 + jnp.mean(g * g, axis=-2) * (1 - b2)
                    denom_r = vr / jnp.maximum(
                        jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                    vhat = denom_r[..., None] * vc[..., None, :]
                    new_v = (vr, vc)
                else:
                    vhat = v * b2 + g * g * (1 - b2)
                    new_v = vhat
                u = g / (jnp.sqrt(vhat / bc2) + eps)
                mf = m.astype(jnp.float32) * b1 + u * (1 - b1)
                upd_ = mf + weight_decay * p.astype(jnp.float32)
                new_p = (p.astype(jnp.float32) - lr_t * upd_).astype(p.dtype)
                return new_p, mf.astype(jnp.bfloat16), new_v

            leaves_p, tdef = jax.tree_util.tree_flatten(params)
            leaves_g = tdef.flatten_up_to(grads)
            leaves_m = tdef.flatten_up_to(state.m)
            leaves_v = tdef.flatten_up_to(state.v)
            outs = [upd(p, g, m, v) for p, g, m, v in
                    zip(leaves_p, leaves_g, leaves_m, leaves_v)]
            new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
            new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
            new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])
            return new_p, OptState(step, new_m, new_v)

        raise ValueError(kind)

    return init, update
