"""Model / run configuration dataclasses for the architecture zoo.

Every assigned architecture instantiates :class:`ModelConfig` with the
exact published numbers (see per-arch modules); smoke tests call
``cfg.reduced()`` for a tiny same-family variant.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["MoEConfig", "MLAConfig", "SSMConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    moe_every: int = 1             # MoE MLP every k-th layer (jamba: 2)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention flavor
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    sliding_window: int | None = None      # window size for local layers
    local_global_ratio: int | None = None  # gemma3: local layers per global
    attn_every: int | None = None          # jamba: attention each k-th layer
    cross_attn_every: int | None = None    # llama-vision: cross each k-th
    vision_tokens: int = 0                 # vlm stub frontend token count
    encoder_layers: int = 0                # whisper enc-dec
    rms_eps: float = 1e-6
    tie_embeddings: bool = False

    moe: MoEConfig | None = None
    n_dense_layers: int = 0                # deepseek: leading dense layers
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    mtp_heads: int = 0                     # deepseek multi-token prediction

    # numerics / memory
    dtype: str = "bfloat16"
    remat: Literal["none", "full", "dots"] = "full"
    attention_impl: Literal["reference", "pallas"] = "reference"
    fsdp: bool = False                     # shard params over data axis too
    optimizer: Literal["adamw", "adafactor", "adamw8bit"] = "adamw"

    # notes for DESIGN/EXPERIMENTS bookkeeping
    source: str = ""

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the embedding shards on any mesh axis."""
        return -(-self.vocab_size // 1024) * 1024

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for the
        MODEL_FLOPS roofline line and memory napkin math."""
        p = self.vocab_padded * self.d_model          # embed
        if not self.tie_embeddings:
            p += self.vocab_padded * self.d_model     # lm head
        total_layers = self.n_layers + self.encoder_layers
        for i in range(total_layers):
            p += self._layer_params(i)
        if self.mtp_heads:
            p += self.mtp_heads * self._layer_params(self.n_layers - 1)
        return p

    def _is_attn_layer(self, i: int) -> bool:
        if self.family in ("ssm",):
            return False
        if self.attn_every:
            return i % self.attn_every == 0
        return True

    def _is_moe_layer(self, i: int) -> bool:
        if self.moe is None or i < self.n_dense_layers:
            return False
        return (i % self.moe.moe_every) == (self.moe.moe_every - 1) \
            if self.moe.moe_every > 1 else True

    def _layer_params(self, i: int) -> int:
        d = self.d_model
        p = 2 * d                                     # norms
        if self._is_attn_layer(i):
            if self.mla is not None:
                c = self.mla
                qh = c.qk_nope_dim + c.qk_rope_dim
                p += d * c.q_lora_rank + c.q_lora_rank * self.n_heads * qh
                p += d * (c.kv_lora_rank + c.qk_rope_dim)
                p += c.kv_lora_rank * self.n_heads * (c.qk_nope_dim +
                                                      c.v_head_dim)
                p += self.n_heads * c.v_head_dim * d
            else:
                p += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        elif self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            h = d_in // s.head_dim
            p += d * (2 * d_in + 2 * s.d_state + h)   # in_proj (x,z,B,C,dt)
            p += d_in * s.conv_width + h + h          # conv, A_log, D
            p += d_in * d                             # out_proj
        if self._is_moe_layer(i):
            m = self.moe
            p += d * m.n_experts                      # router
            p += (m.n_experts + m.n_shared) * 3 * d * m.d_expert
        else:
            p += 3 * d * self.d_ff                    # swiglu
        return p

    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.n_params()
        p = self.n_params()
        m = self.moe
        n_moe_layers = sum(self._is_moe_layer(i)
                           for i in range(self.n_layers))
        inactive = (m.n_experts - m.top_k) * 3 * self.d_model * m.d_expert
        return p - n_moe_layers * inactive

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        changes: dict = dict(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.mla is None else 4,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            vision_tokens=16 if self.vision_tokens else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            remat="none",
            dtype="float32",
            n_dense_layers=1 if self.n_dense_layers else 0,
            mtp_heads=min(self.mtp_heads, 1),
        )
        if self.moe is not None:
            # capacity_factor E/k makes the reduced config DROPLESS so the
            # prefill+decode == full-forward consistency tests are exact
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_expert=64,
                n_shared=min(self.moe.n_shared, 1), capacity_factor=2.0)
        if self.mla is not None:
            changes["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                       qk_nope_dim=16, qk_rope_dim=16,
                                       v_head_dim=32)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=16)
        if self.attn_every:
            changes["n_layers"] = self.attn_every  # one full superblock
        if self.local_global_ratio:
            changes["n_layers"] = self.local_global_ratio + 1
            changes["sliding_window"] = 8
        if self.cross_attn_every:
            changes["n_layers"] = self.cross_attn_every
        return dataclasses.replace(self, **changes)
