"""gemma3-12b [dense] — 48L d3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
5:1 local:global attention interleave (sliding window 1024), 128k context,
head_dim 256. Single rope_theta simplification documented in DESIGN.md.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    local_global_ratio=5,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
