"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) d_ff=24576
vocab=65536; attn:mamba 1:7 interleave, MoE 16 experts top-2 every other
layer. SSM blocks use Mamba-2 SSD (adaptation noted in DESIGN.md).
[arXiv:2403.19887; hf]"""
from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    rope_theta=10_000.0,
    attn_every=8,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, moe_every=2),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    fsdp=True,
    source="arXiv:2403.19887; hf",
)
