"""llama-3.2-vision-90b [vlm] — 100L d8192 64H (GQA kv=8) d_ff=28672
vocab=128256; cross-attention image layers every 5th layer (20 of 100).
Vision frontend is a STUB: input_specs provides pre-projected patch
embeddings [B, 1600, d_model]. [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    vision_tokens=1600,
    fsdp=True,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
