"""qwen3-moe-235b-a22b [moe] — 94L d4096 64H (GQA kv=4) expert_ff=1536
vocab=151936; 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,          # per-expert FFN width (all MLPs are MoE)
    vocab_size=151936,
    rope_theta=1_000_000.0,
    qk_norm=True,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
