"""Assigned input shapes and their ShapeDtypeStruct input specs.

The four LM shape cells (tasking spec):
  train_4k     seq 4,096    global_batch 256   -> train_step
  prefill_32k  seq 32,768   global_batch 32    -> serve prefill
  decode_32k   seq 32,768   global_batch 128   -> serve_step (1 new token,
                                                  KV cache of seq_len)
  long_500k    seq 524,288  global_batch 1     -> long-context decode; only
                                                  sub-quadratic archs
                                                  (mamba2, jamba)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import ModelConfig

__all__ = ["ShapeCell", "SHAPES", "input_specs", "is_applicable",
           "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def is_applicable(cfg: ModelConfig, cell: ShapeCell) -> bool:
    return skip_reason(cfg, cell) is None


def skip_reason(cfg: ModelConfig, cell: ShapeCell) -> str | None:
    if cell.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.arch_id} is a full-attention architecture "
                "(gemma3's 5:1 local:global still has quadratic global "
                "layers) — skipped per tasking rule, see DESIGN.md")
    return None


def _f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs for the model-input batch of a cell (no device
    allocation — the dry-run lowers against these)."""
    B = cell.global_batch
    S = cell.seq_len if cell.kind != "decode" else 1
    dt = jnp.dtype(cfg.dtype)
    batch: dict = {"tokens": _f((B, S), jnp.int32)}
    if cfg.family == "vlm" and cell.kind != "decode":
        batch["vision"] = _f((B, cfg.vision_tokens, cfg.d_model), dt)
    if cfg.family == "audio" and cell.kind != "decode":
        batch["audio_frames"] = _f((B, cell.seq_len, cfg.d_model), dt)
    return batch


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """All abstract inputs for the cell's step function:
      train:   {params, opt_state?, batch}   (assembled by launch.dryrun)
      prefill: {params, batch}
      decode:  {params, cache, token}
    Only the batch/cache parts are produced here; params come from
    models.abstract_params.
    """
    from repro.models.model import abstract_cache

    out = {"batch": batch_specs(cfg, cell)}
    if cell.kind == "decode":
        out["cache"] = abstract_cache(cfg, cell.global_batch, cell.seq_len)
    return out
