"""Sharding policy: parameter / batch / cache PartitionSpecs per mesh.

Rules (DESIGN.md §4):
  * DP: the batch axis shards over every non-"model" mesh axis
    (("pod","data") multi-pod) when divisible;
  * TP: column-parallel in-projections (last dim on "model"),
    row-parallel out-projections (first semantic dim on "model");
  * EP: MoE expert dim on "model";
  * FSDP/ZeRO: cfg.fsdp additionally shards the complementary weight dim
    over "data" (optimizer state inherits the param spec = ZeRO-1);
  * SP: decode KV caches shard the sequence dim over "model" (and over
    ("data","model") for the batch-1 long-context cell);
  * every rule is guarded by divisibility — a dim that does not divide by
    the axis size stays replicated (recorded as such in the dry-run JSON).

Leading stack dims introduced by scan-over-layers are never sharded.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .base import ModelConfig
from .shapes import ShapeCell

__all__ = ["param_specs", "batch_shardings", "cache_shardings",
           "batch_axes_for", "logits_sharding"]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= _axis_size(mesh, a)
        return out
    return mesh.shape[axis]


def _if_div(dim: int, axis, mesh: Mesh):
    """Use axis only if it divides dim."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 \
        else None


def batch_axes_for(mesh: Mesh, batch: int):
    """Largest prefix of the non-model axes whose product divides batch."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    while axes and batch % _axis_size(mesh, axes) != 0:
        axes = axes[:-1]
    return axes if axes else None


# -- parameters ---------------------------------------------------------------

# (semantic_ndim, spec builder) keyed by parameter leaf name. The builder
# receives (shape_of_semantic_dims, model_axis, fsdp_axis) and returns the
# semantic PartitionSpec dims.
def _col(shape, model, fsdp):     # [in, out] column parallel
    return (fsdp, model)


def _row(shape, model, fsdp):     # [in, out] row parallel
    return (model, fsdp)


def _expert_col(shape, model, fsdp):   # [E, in, out]
    return (model, fsdp, None)


def _expert_row(shape, model, fsdp):   # [E, in, out]
    return (model, None, fsdp)


def _vocab(shape, model, fsdp):   # [V, d]
    return (model, fsdp)


def _repl(shape, model, fsdp):
    return tuple(None for _ in shape)


_RULES: dict[str, tuple[int, Any]] = {
    "embed": (2, _vocab), "lm_head": (2, _vocab),
    "wq": (2, _col), "wk": (2, _col), "wv": (2, _col), "wo": (2, _row),
    "w_gate": (2, _col), "w_up": (2, _col), "w_down": (2, _row),
    "w_dq": (2, _col), "w_uq": (2, _col), "w_dkv": (2, _repl),
    "w_uk": (2, _col), "w_uv": (2, _col),
    "router": (2, _repl),
    "shared_gate": (2, _col), "shared_up": (2, _col),
    "shared_down": (2, _row),
    "in_proj": (2, _col), "out_proj": (2, _row),
    "conv_w": (2, lambda s, m, f: (None, m)),
    "proj": (2, _col),
}

_MOE_RULES = {"w_gate": (3, _expert_col), "w_up": (3, _expert_col),
              "w_down": (3, _expert_row)}


def _leaf_spec(path: tuple, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    in_moe = "moe" in names
    rules = _MOE_RULES if (in_moe and name in _MOE_RULES) else _RULES
    if name not in rules:
        return P()                                 # norms, scalars, biases
    sem_ndim, builder = rules[name]
    shape = leaf.shape
    if len(shape) < sem_ndim:
        return P()
    sem_shape = shape[-sem_ndim:]
    model = "model" if "model" in mesh.axis_names else None
    fsdp = "data" if (cfg.fsdp and "data" in mesh.axis_names) else None
    dims = list(builder(sem_shape, model, fsdp))
    # divisibility guard per dim
    dims = [_if_div(sem_shape[i], dims[i], mesh) for i in range(sem_ndim)]
    lead = (None,) * (len(shape) - sem_ndim)
    return P(*lead, *dims)


def param_specs(cfg: ModelConfig, params_abstract, mesh: Mesh):
    """Pytree of NamedSharding matching the abstract params."""
    flat = jax.tree_util.tree_flatten_with_path(params_abstract)[0]
    specs = {}
    out = jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _leaf_spec(path, leaf, cfg,
                                                          mesh)),
        params_abstract)
    del flat, specs
    return out


# -- batch / cache ------------------------------------------------------------

def batch_shardings(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> dict:
    b = batch_axes_for(mesh, cell.global_batch)
    ns = lambda *spec: NamedSharding(mesh, P(*spec))  # noqa: E731
    out = {"tokens": ns(b, None)}
    if cfg.family == "vlm" and cell.kind != "decode":
        out["vision"] = ns(b, None, None)
    if cfg.family == "audio" and cell.kind != "decode":
        out["audio_frames"] = ns(b, None, None)
    return out


def _seq_axes(cell: ShapeCell, mesh: Mesh, seq: int):
    """Sequence-dim sharding for decode caches (SP)."""
    if cell.global_batch == 1:
        cand = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    else:
        cand = ("model",) if "model" in mesh.axis_names else ()
    cand = cand if cand and seq % _axis_size(mesh, cand) == 0 else None
    return cand


def cache_shardings(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                    cache_abstract):
    """Shardings for the decode cache pytree (init_cache structure)."""
    b = batch_axes_for(mesh, cell.global_batch)
    model = "model" if "model" in mesh.axis_names else None

    def spec(path, leaf) -> NamedSharding:
        names = [getattr(k, "key", getattr(k, "name", str(k)))
                 for k in path]
        shape = leaf.shape
        if names[-1] == "pos" or len(shape) == 0:
            return NamedSharding(mesh, P())
        # all cache leaves: [n_super, B, ...]
        if "mamba" in names:
            if len(shape) == 5:        # [ns, B, H, P, N]
                h_ax = _if_div(shape[2], model, mesh)
                return NamedSharding(mesh, P(None, b, h_ax, None, None))
            # conv [ns, B, W-1, conv_dim]
            c_ax = _if_div(shape[3], model, mesh)
            return NamedSharding(mesh, P(None, b, None, c_ax))
        if "cross_kv" in names:        # [ns, B, V, KV, HD] read-only memory
            v_ax = _if_div(shape[2], model, mesh)
            return NamedSharding(mesh, P(None, b, v_ax, None, None))
        if "mla" in names:             # [ns, B, S, r]
            s_ax = _seq_axes(cell, mesh, shape[2])
            return NamedSharding(mesh, P(None, b, s_ax, None))
        if len(shape) == 5:            # kv [ns, B, S, KV, HD]
            s_ax = _seq_axes(cell, mesh, shape[2])
            return NamedSharding(mesh, P(None, b, s_ax, None, None))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec, cache_abstract)


def logits_sharding(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh):
    b = batch_axes_for(mesh, cell.global_batch)
    model = "model" if "model" in mesh.axis_names else None
    v_ax = _if_div(cfg.vocab_padded, model, mesh)
    return NamedSharding(mesh, P(b, None, v_ax))
