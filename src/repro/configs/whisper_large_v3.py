"""whisper-large-v3 [audio] — enc-dec 32L+32L d1280 20H d_ff=5120
vocab=51866; conv frontend is a STUB: input_specs provides precomputed
frame embeddings [B, S_enc, d_model]. [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,          # whisper uses MHA (kv == q heads)
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    rope_theta=10_000.0,    # decoder positions (learned-pos adaptation)
    source="arXiv:2212.04356; unverified",
)
