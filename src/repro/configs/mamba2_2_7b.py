"""mamba2-2.7b [ssm] — 64L d2560, attn-free, ssm_state=128, SSD.
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,              # unused (attention-free)
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,                 # no MLP: the mamba block IS the layer
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    source="arXiv:2405.21060; unverified",
)
