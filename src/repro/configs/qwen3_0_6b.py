"""qwen3-0.6b [dense] — 28L d1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
qk_norm; Qwen3 fixes head_dim=128 independent of d_model.
[hf:Qwen/Qwen3-8B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B; hf",
)
