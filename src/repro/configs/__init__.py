"""Config registry: the 10 assigned architectures + the paper's own
graph-kernel workload, selectable via --arch <id>."""
from .base import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from .shapes import SHAPES, ShapeCell, batch_specs, input_specs, \
    is_applicable, skip_reason

from . import (deepseek_v3_671b, gemma3_12b, jamba_1_5_large_398b,
               llama_3_2_vision_90b, mamba2_2_7b, phi4_mini_3_8b,
               qwen3_0_6b, qwen3_14b, qwen3_moe_235b_a22b, whisper_large_v3)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.arch_id: m.CONFIG
    for m in (phi4_mini_3_8b, qwen3_14b, qwen3_0_6b, gemma3_12b,
              qwen3_moe_235b_a22b, deepseek_v3_671b, llama_3_2_vision_90b,
              whisper_large_v3, mamba2_2_7b, jamba_1_5_large_398b)
}

__all__ = ["MLAConfig", "ModelConfig", "MoEConfig", "SSMConfig", "SHAPES",
           "ShapeCell", "batch_specs", "input_specs", "is_applicable",
           "skip_reason", "ARCHS"]
