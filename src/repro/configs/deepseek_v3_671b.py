"""deepseek-v3-671b [moe] — 61L d7168 128H d_expert=2048 vocab=129280;
MLA (q_lora 1536, kv_lora 512, rope 64), 1 shared + 256 routed top-8, MTP,
first 3 layers dense (d_ff 18432). [arXiv:2412.19437; hf]"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,     # nominal (MLA replaces GQA; latent cache rank 512)
    head_dim=128,
    d_ff=18432,         # the 3 leading dense layers
    vocab_size=129280,
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1),
    n_dense_layers=3,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    mtp_heads=1,
    optimizer="adafactor",
    fsdp=True,
    source="arXiv:2412.19437; hf",
)
