"""End-to-end LM training launcher.

Runs on whatever mesh is available (local CPU mesh for the examples /
smoke runs; the production mesh on a fleet). Fault tolerance: rolling
CRC-checked checkpoints (train state + data cursor) with automatic resume.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ModelConfig
from repro.distributed.checkpoint import load_array_checkpoint, \
    save_array_checkpoint
from repro.models.model import init_params
from repro.train.steps import make_train_step

__all__ = ["TrainRun", "run_training", "synthetic_token_stream"]


def synthetic_token_stream(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM data: a mixture of repeated n-grams and
    noise so the loss has learnable structure. Step-indexed => a restart
    resumes the exact stream (data-pipeline determinism)."""
    def batch_at(step: int):
        rng = np.random.default_rng(seed + step)
        base = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64)
        # inject learnable bigram structure: token 2k follows 2k+1
        pair = rng.integers(0, vocab // 2, (batch, 1))
        base[:, 0::2] = 2 * pair % vocab
        base[:, 1::2] = (2 * pair + 1) % vocab
        noise = rng.random((batch, seq + 1)) < 0.1
        base = np.where(noise, rng.integers(0, vocab, base.shape), base)
        return {"tokens": jnp.asarray(base[:, :seq], jnp.int32)}
    return batch_at


@dataclasses.dataclass
class TrainRun:
    cfg: ModelConfig
    steps: int = 100
    batch: int = 8
    seq: int = 128
    lr: float = 3e-4
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    warmup_steps: int = 30
    seed: int = 0
    log_every: int = 10


def run_training(run: TrainRun, extra_batch_fn=None):
    cfg = run.cfg
    opt_init, step_fn = make_train_step(cfg, lr=run.lr,
                                        warmup_steps=run.warmup_steps)
    params = init_params(cfg, jax.random.key(run.seed))
    opt_state = opt_init(params)
    start_step = 0
    state = (params, opt_state)
    if run.ckpt_dir and os.path.isdir(run.ckpt_dir) and any(
            p.startswith("ckpt_") for p in os.listdir(run.ckpt_dir)):
        state, start_step = load_array_checkpoint(run.ckpt_dir, state)
        print(f"[train] resumed from step {start_step}")
    params, opt_state = state

    data = synthetic_token_stream(cfg.vocab_size, run.batch, run.seq,
                                  run.seed)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for step in range(start_step, run.steps):
        batch = data(step)
        if extra_batch_fn:
            batch.update(extra_batch_fn(step))
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        if step % run.log_every == 0 or step == run.steps - 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({dt:.1f}s)", flush=True)
        if run.ckpt_dir and (step + 1) % run.ckpt_every == 0:
            save_array_checkpoint(run.ckpt_dir, step + 1,
                                  (params, opt_state))
    if run.ckpt_dir:
        save_array_checkpoint(run.ckpt_dir, run.steps, (params, opt_state))
    return params, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    _, losses = run_training(TrainRun(
        cfg=cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, ckpt_dir=args.ckpt_dir))
    first, last = losses[0][1], losses[-1][1]
    print(f"[train] loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
