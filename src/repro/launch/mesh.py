"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax use;
smoke tests and benchmarks must keep seeing the real single CPU device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod v5e 16x16 (256 chips) or 2-pod 2x16x16 (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as a (data, model) mesh — used by
    the runnable examples and tests on CPU."""
    n = len(jax.devices())
    if n % model_axis:
        raise ValueError(f"{n} devices not divisible by model={model_axis}")
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
