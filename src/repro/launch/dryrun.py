import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against ShapeDtypeStruct inputs (no allocation), print
memory_analysis / cost_analysis, and extract the collective schedule for
the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST stay the first statements of this module: jax
locks the device count at first initialization, and the dry-run needs 512
host placeholder devices. Do not import this module from tests that need
the real device count.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import functools
import json
import re
import sys
import time
import traceback

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, ShapeCell, skip_reason
from repro.configs.base import ModelConfig
from repro.configs.shapes import batch_specs
from repro.configs.sharding import (batch_shardings, cache_shardings,
                                    batch_axes_for, logits_sharding,
                                    param_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.model import abstract_cache, abstract_params
from repro.train.optimizer import make_optimizer
from repro.train.steps import loss_fn, make_decode_step, make_prefill_step

# v5e hardware constants (per chip) — see EXPERIMENTS.md §Roofline
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
                "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(line: str) -> int:
    """Byte size of the result shape on an HLO instruction line (handles
    tuple-shaped results by summing components)."""
    head = line.split("=", 1)[0] if "=" in line else line
    # shapes appear right after '=': take the segment before the opcode
    rhs = line.split("=", 1)[1] if "=" in line else line
    op_pos = min((rhs.find(c) for c in _COLLECTIVES if c in rhs),
                 default=-1)
    seg = rhs[:op_pos] if op_pos > 0 else rhs
    total = 0
    for dt, dims in _SHAPE_RE.findall(seg):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    del head
    return total


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP_RE2.search(line)
    if m:
        return int(m.group(2))
    return 1


def collective_stats(hlo_text: str) -> dict:
    """Per-device link-bytes per collective type, ring-algorithm model:
      all-reduce:          2 * B * (g-1)/g      (B = result bytes)
      all-gather:              B * (g-1)/g
      reduce-scatter:          B * (g-1)        (result is the shard)
      all-to-all:              B * (g-1)/g
      collective-permute:      B
    """
    stats = {c: {"count": 0, "bytes": 0.0, "link_bytes": 0.0}
             for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        l = line.strip()
        if "=" not in l:
            continue
        opcode_part = l.split("=", 1)[1]
        for c in _COLLECTIVES:
            # match opcode tokens like 'all-reduce(' / 'all-gather-start('
            if re.search(rf"\b{c}(-start)?\(", opcode_part):
                b = _result_bytes(l)
                g = _group_size(l)
                if c == "all-reduce":
                    lb = 2 * b * (g - 1) / max(g, 1)
                elif c == "reduce-scatter":
                    lb = b * (g - 1)
                elif c == "collective-permute":
                    lb = b
                else:
                    lb = b * (g - 1) / max(g, 1)
                stats[c]["count"] += 1
                stats[c]["bytes"] += b
                stats[c]["link_bytes"] += lb
                break
    stats["total_link_bytes"] = sum(
        v["link_bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


def sharded_bytes(abstract, shardings) -> float:
    """Per-device bytes of a pytree under the given shardings (fallback /
    cross-check for memory_analysis)."""
    total = 0.0
    flat_a = jax.tree.leaves(abstract)
    flat_s = jax.tree.leaves(shardings,
                             is_leaf=lambda x: isinstance(x, NamedSharding))
    for a, s in zip(flat_a, flat_s):
        size = np.prod(a.shape) * a.dtype.itemsize if a.shape else \
            a.dtype.itemsize
        shards = 1
        for dim, ax in enumerate(s.spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for one in axes:
                shards *= s.mesh.shape[one]
        total += size / shards
    return total


# ---------------------------------------------------------------------------
# per-cell step builders
# ---------------------------------------------------------------------------

def _opt_shardings(pspecs, opt_abstract, mesh: Mesh):
    """Optimizer state shardings inheriting the parameter specs
    (ZeRO-1: state shards wherever the param shards)."""
    pdef = jax.tree_util.tree_structure(
        jax.tree.map(lambda x: 0, pspecs,
                     is_leaf=lambda x: isinstance(x, NamedSharding)))
    flat_p = jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, NamedSharding))

    def like(ps: NamedSharding, sub):
        spec = ps.spec

        def leaf_spec(leaf):
            nd = len(leaf.shape)
            if nd == len(spec):
                return NamedSharding(mesh, spec)
            if nd == len(spec) - 1:      # factored row stat / quant scale
                return NamedSharding(mesh, P(*spec[:-1]))
            return NamedSharding(mesh, P())
        return jax.tree.map(leaf_spec, sub)

    def build(opt_sub):
        flat_o = pdef.flatten_up_to(opt_sub)
        return jax.tree_util.tree_unflatten(
            pdef, [like(ps, o) for ps, o in zip(flat_p, flat_o)])

    from repro.train.optimizer import OptState
    return OptState(step=NamedSharding(mesh, P()),
                    m=build(opt_abstract.m), v=build(opt_abstract.v))


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh):
    """Returns (fn, args_abstract, in_shardings, out_shardings,
    donate_argnums)."""
    params_a = abstract_params(cfg)
    pspecs = param_specs(cfg, params_a, mesh)
    bspecs = batch_shardings(cfg, cell, mesh)
    batch_a = batch_specs(cfg, cell)

    if cell.kind == "train":
        opt_init, opt_update = make_optimizer(cfg.optimizer)

        def train_step(params, opt_state, batch):
            grads, metrics = jax.grad(
                functools.partial(loss_fn, cfg), has_aux=True)(params, batch)
            new_params, new_opt = opt_update(grads, opt_state, params)
            return new_params, new_opt, metrics

        opt_a = jax.eval_shape(opt_init, params_a)
        ospecs = _opt_shardings(pspecs, opt_a, mesh)
        mspec = {"nll": NamedSharding(mesh, P()),
                 "aux": NamedSharding(mesh, P()),
                 "loss": NamedSharding(mesh, P())}
        if cfg.mtp_heads:
            mspec["mtp_nll"] = NamedSharding(mesh, P())
        return (train_step, (params_a, opt_a, batch_a),
                (pspecs, ospecs, bspecs), (pspecs, ospecs, mspec), (0, 1))

    if cell.kind == "prefill":
        cache_a = abstract_cache(cfg, cell.global_batch, cell.seq_len)
        cspecs = cache_shardings(cfg, cell, mesh, cache_a)
        step = make_prefill_step(cfg)
        lspec = logits_sharding(cfg, cell, mesh)
        # output cache shapes can differ from input (cross-kv memory len):
        out_cache_a = jax.eval_shape(step, params_a, batch_a, cache_a)[1]
        out_cspecs = cache_shardings(cfg, cell, mesh, out_cache_a)
        return (step, (params_a, batch_a, cache_a),
                (pspecs, bspecs, cspecs), (lspec, out_cspecs), (2,))

    if cell.kind == "decode":
        cache_a = abstract_cache(cfg, cell.global_batch, cell.seq_len)
        cspecs = cache_shardings(cfg, cell, mesh, cache_a)
        step = make_decode_step(cfg)
        b = batch_axes_for(mesh, cell.global_batch)
        token_a = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
        tspec = NamedSharding(mesh, P(b, None))
        lspec = logits_sharding(cfg, cell, mesh)
        return (step, (params_a, cache_a, token_a),
                (pspecs, cspecs, tspec), (lspec, cspecs), (1,))

    raise ValueError(cell.kind)


# -- the paper's own workload on the production mesh -------------------------

def build_gram_cell(mesh: Mesh, variant: str = "baseline",
                    n_pairs: int = 512, nodes: int = 128):
    """The MGK Gram pair-step (paper technique) as a dry-run cell: pairs
    shard over pod x data, product-system rows over model.

    Variants (§Perf cell C):
      faithful      paper-faithful on-the-fly elementwise XMV (Alg. 2)
      baseline      beyond-paper rank-12 MXU sandwich XMV
      rank8 / rank6 truncated feature rank (documented error <=1e-4/1e-3)
      b2048         4x pair batch per step (amortizes fixed work)
    CG runs a fixed-48-iteration scan (visible to the static profile;
    production buckets solve in lockstep anyway).
    """
    from repro.core.base_kernels import KroneckerDelta, SquareExponential
    from repro.core.graph import GraphBatch
    from repro.core.mgk import mgk_pairs
    from repro.distributed.gram import pair_shardings

    method = "lowrank"
    rank = 12
    for part in variant.split("+"):
        if part == "faithful":
            method = "elementwise"
        elif part.startswith("rank"):
            rank = int(part[4:])
        elif part == "b2048":
            n_pairs = 2048
        elif part in ("baseline", ""):
            pass
        else:
            raise ValueError(f"unknown gram variant {part!r}")

    B, n = n_pairs, nodes
    f32 = jnp.float32

    def gb_abstract():
        return GraphBatch(
            adjacency=jax.ShapeDtypeStruct((B, n, n), f32),
            edge_labels=jax.ShapeDtypeStruct((B, n, n), f32),
            vertex_labels=jax.ShapeDtypeStruct((B, n), f32),
            start_prob=jax.ShapeDtypeStruct((B, n), f32),
            stop_prob=jax.ShapeDtypeStruct((B, n), f32),
            degrees=jax.ShapeDtypeStruct((B, n), f32),
            node_mask=jax.ShapeDtypeStruct((B, n), f32),
            n_nodes=jax.ShapeDtypeStruct((B,), jnp.int32),
        )

    (g1_s, g2_s), out_s = pair_shardings(mesh)
    vk = KroneckerDelta(0.5, n_labels=8)
    ek = SquareExponential(1.0, rank=rank)

    def step(g1, g2):
        res = mgk_pairs(g1, g2, vk, ek, method=method, tol=1e-8,
                        max_iter=64, fixed_iters=48)
        return res.values, res.iterations

    vals_s = NamedSharding(mesh, out_s.values.spec)
    return (step, (gb_abstract(), gb_abstract()), (g1_s, g2_s),
            (vals_s, vals_s), ())


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    n_active = cfg.n_active_params()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    tokens = cell.global_batch * 1
    return 2.0 * n_active * tokens


def apply_variant(cfg: ModelConfig, variant: str) -> ModelConfig:
    """§Perf variants: named config transforms layered onto an arch.
    Multiple transforms combine with '+' (e.g. 'chunked+remat_dots')."""
    import dataclasses
    for part in variant.split("+"):
        if part in ("baseline", "faithful_elementwise", "opt", "") or \
                part.startswith(("moe_", "label")):
            continue   # code-level variants: label only
        if part == "chunked":
            cfg = dataclasses.replace(cfg, attention_impl="chunked")
        elif part == "remat_dots":
            cfg = dataclasses.replace(cfg, remat="dots")
        elif part == "remat_none":
            cfg = dataclasses.replace(cfg, remat="none")
        elif part == "fsdp":
            cfg = dataclasses.replace(cfg, fsdp=True)
        elif part == "adafactor":
            cfg = dataclasses.replace(cfg, optimizer="adafactor")
        elif part == "adamw8bit":
            cfg = dataclasses.replace(cfg, optimizer="adamw8bit")
        else:
            raise ValueError(f"unknown variant part {part!r}")
    return cfg


def run_cell(arch: str, shape: str, mesh_kind: str,
             variant: str = "baseline") -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "variant": variant,
                 "mesh_shape": dict(zip(mesh.axis_names,
                                        mesh.devices.shape)),
                 "n_devices": int(mesh.devices.size)}
    t0 = time.time()
    if arch == "mgk-gram":
        fn, args, in_s, out_s, donate = build_gram_cell(mesh, variant)
        rec["n_params"] = 0
        cell = None
    else:
        cfg = apply_variant(ARCHS[arch], variant)
        cell = SHAPES[shape]
        reason = skip_reason(cfg, cell)
        if reason:
            rec["status"] = "skipped"
            rec["skip_reason"] = reason
            return rec
        fn, args, in_s, out_s, donate = build_cell(cfg, cell, mesh)
        rec["n_params"] = cfg.n_params()
        rec["n_active_params"] = cfg.n_active_params()
        rec["model_flops"] = model_flops(cfg, cell)

    with mesh:
        jitted = jax.jit(fn, in_shardings=in_s, out_shardings=out_s,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        # ---- memory ----
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory_analysis"] = {
                    k: int(getattr(ma, k)) for k in
                    ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes")
                    if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not implement it
            rec["memory_analysis_error"] = str(e)
        rec["arg_bytes_per_device"] = sharded_bytes(args, in_s)

        # ---- flops / bytes ----
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and
                k in ("flops", "bytes accessed", "bytes accessed output",
                      "optimal_seconds", "utilization operand 0 {}")}
            rec["hlo_flops"] = float(ca.get("flops", -1.0))
            rec["hlo_bytes"] = float(ca.get("bytes accessed", -1.0))
        except Exception as e:
            rec["cost_analysis_error"] = str(e)

        # ---- collectives + loop-trip-corrected static profile ----
        try:
            txt = compiled.as_text()
        except Exception:
            txt = lowered.as_text()
        rec["collectives"] = collective_stats(txt)
        rec["hlo_lines"] = txt.count("\n")
        from repro.analysis import analyze_hlo
        hc = analyze_hlo(txt)
        rec["corrected"] = {
            "flops": hc.flops,
            "hbm_bytes": hc.hbm_bytes,
            "total_link_bytes": hc.total_link_bytes,
            "collectives": hc.collectives,
            "n_while": hc.n_while,
            "unknown_trip_loops": hc.unknown_trip_loops,
        }

    # ---- roofline terms (per device), from the LOOP-CORRECTED profile ----
    # (raw cost_analysis counts while bodies once; see analysis/hlo_cost.py)
    link_bytes = rec["corrected"]["total_link_bytes"]
    flops = rec["corrected"]["flops"]
    hbm_bytes = rec["corrected"]["hbm_bytes"]
    rec["roofline"] = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": link_bytes / ICI_BW,
    }
    dom = max(rec["roofline"], key=rec["roofline"].get)
    rec["roofline"]["dominant"] = dom
    if arch != "mgk-gram" and rec.get("model_flops"):
        total_hlo = flops * rec["n_devices"]
        rec["roofline"]["model_flops_ratio"] = (
            rec["model_flops"] / total_hlo if total_hlo > 0 else None)
    rec["status"] = "ok"
    return rec


def all_cells() -> list[tuple[str, str]]:
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    cells.append(("mgk-gram", "gram_block"))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            name = f"{arch}__{shape}__{mk}__{args.variant}"
            path = os.path.join(args.out, name + ".json")
            if os.path.exists(path):
                print(f"[skip existing] {name}")
                continue
            print(f"[dryrun] {name} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mk, args.variant)
            except Exception:
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "variant": args.variant, "status": "error",
                       "error": traceback.format_exc()}
                failures += 1
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec.get("status")
            ro = rec.get("roofline", {})
            print(f"  -> {status} compile={rec.get('compile_s')}s "
                  f"dominant={ro.get('dominant')} "
                  f"compute={ro.get('compute_s', 0):.2e}s "
                  f"memory={ro.get('memory_s', 0):.2e}s "
                  f"collective={ro.get('collective_s', 0):.2e}s",
                  flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
