"""Batched serving launcher: continuous-batching-style decode loop.

Requests arrive with different prompt lengths; the server left-pads...
no — it buckets requests, prefills each bucket, then decodes the union
batch step by step, retiring finished sequences and admitting queued ones
into freed slots (slot reuse = the serving analogue of the paper's
inter-block load balancing).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ModelConfig
from repro.models.model import decode_step, forward, init_cache, init_params

__all__ = ["ServeLoop", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [L] int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Fixed-slot continuous batching decoder."""

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 s_max: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.s_max = s_max
        self.cache = init_cache(cfg, slots, s_max)
        self.active: list[Request | None] = [None] * slots
        self.positions = np.zeros(slots, np.int32)
        self.budget = np.zeros(slots, np.int32)
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, t))

    def _prefill_slot(self, slot: int, req: Request):
        # single-slot prefill into a fresh per-slot cache, then merge
        L = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        one = init_cache(self.cfg, 1, self.s_max)
        logits, one, _ = forward(self.cfg, self.params, batch, cache=one)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        # merge slot cache into the batch cache
        def merge(big, small):
            if big.ndim == 0 or small is None:
                return big
            return big.at[:, slot].set(small[:, 0]) \
                if big.ndim >= 2 else big
        self.cache = jax.tree.map(
            lambda b, s: merge(b, s) if hasattr(b, "ndim") and b.ndim >= 2
            else b, self.cache, one)
        self.positions[slot] = L
        self.budget[slot] = req.max_new - 1
        self.active[slot] = req

    def step(self, queue: list[Request]):
        """One server tick: admit, decode one token for every live slot."""
        for slot in range(self.slots):
            if self.active[slot] is None and queue:
                self._prefill_slot(slot, queue.pop(0))
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return
        # batch decode: every slot advances with its own position; slots
        # share the jitted step (cache["pos"] is global, so positions must
        # be uniform — the loop keeps them uniform by admission policy;
        # stragglers pad with their last token)
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            toks[s, 0] = self.active[s].out[-1]
        self.cache["pos"] = jnp.asarray(int(max(self.positions[live])),
                                        jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        for s in live:
            req = self.active[s]
            req.out.append(int(nxt[s]))
            self.positions[s] += 1
            self.budget[s] -= 1
            if self.budget[s] <= 0:
                req.done = True
                self.active[s] = None

    def run(self, requests: list[Request]):
        queue = list(requests)
        ticks = 0
        while queue or any(a is not None for a in self.active):
            self.step(queue)
            ticks += 1
            if ticks > 10_000:
                raise RuntimeError("serve loop did not converge")
        return requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args(argv)
    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        rng.integers(4, 17)).astype(np.int32),
                    max_new=8)
            for i in range(args.requests)]
    loop = ServeLoop(cfg, params, slots=4, s_max=64)
    done = loop.run(reqs)
    for r in done:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} out={r.out}")
    print(f"[serve] completed {len(done)} requests")


if __name__ == "__main__":
    main()
