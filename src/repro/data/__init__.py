"""Dataset pipeline: synthetic graph generators (paper Sec. VI-A) and
molecule-like real-world dataset surrogates (paper Sec. VI-B), plus the
padding / bucketing loader that feeds the solver fixed shapes."""
from .synthetic import barabasi_albert, newman_watts_strogatz, \
    make_synthetic_dataset
from .molecules import make_pdb_like_dataset, make_drugbank_like_dataset
from .loader import BucketedDataset, bucket_graphs, gram_tile_blocks, \
    pair_blocks

__all__ = [
    "barabasi_albert", "newman_watts_strogatz", "make_synthetic_dataset",
    "make_pdb_like_dataset", "make_drugbank_like_dataset",
    "BucketedDataset", "bucket_graphs", "pair_blocks",
    "gram_tile_blocks",
]
