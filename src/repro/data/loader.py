"""Padding / bucketing loader.

Variable-size graphs must become fixed jit shapes. Strategy (DESIGN.md §4):

1. Bucket graphs by padded size (multiples of the octile edge, capped
   buckets chosen from the dataset's size histogram).
2. Within a bucket, any subset batches into one GraphBatch.
3. All-pairs work is expressed as *pair blocks* — (bucket_i, bucket_j)
   chunks of bounded element count — which are the scheduling/checkpointing
   unit of the distributed Gram driver.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from repro.core.graph import Graph, GraphBatch, batch_from_graphs

__all__ = ["BucketedDataset", "bucket_graphs", "pair_blocks",
           "gram_tile_blocks", "PairBlock"]


def _bucket_sizes(sizes: np.ndarray, multiple_of: int,
                  max_buckets: int) -> list[int]:
    """Choose bucket boundaries from the size histogram: quantile-spaced,
    rounded up to the tile multiple (keeps padding waste bounded while
    keeping the number of distinct jit shapes small)."""
    padded = (-(-sizes // multiple_of) * multiple_of).astype(int)
    uniq = np.unique(padded)
    if len(uniq) <= max_buckets:
        return [int(u) for u in uniq]
    qs = np.linspace(0, 1, max_buckets)
    bounds = sorted({int(-(-np.quantile(padded, q) // multiple_of)
                         * multiple_of) for q in qs})
    if bounds[-1] < padded.max():
        bounds.append(int(padded.max()))
    return bounds


@dataclasses.dataclass(frozen=True)
class Bucket:
    pad_to: int
    indices: np.ndarray  # dataset indices of member graphs


@dataclasses.dataclass
class BucketedDataset:
    graphs: list[Graph]
    buckets: list[Bucket]
    multiple_of: int

    def __len__(self) -> int:
        return len(self.graphs)

    def bucket_of(self, idx: int) -> int:
        for bi, b in enumerate(self.buckets):
            if idx in b.indices:
                return bi
        raise KeyError(idx)

    def batch(self, indices: Sequence[int], pad_to: int) -> GraphBatch:
        return batch_from_graphs([self.graphs[i] for i in indices],
                                 pad_to=pad_to,
                                 multiple_of=self.multiple_of)


def bucket_graphs(graphs: Sequence[Graph], multiple_of: int = 8,
                  max_buckets: int = 8) -> BucketedDataset:
    sizes = np.array([g.n_nodes for g in graphs])
    bounds = _bucket_sizes(sizes, multiple_of, max_buckets)
    assigned = [[] for _ in bounds]
    for i, s in enumerate(sizes):
        for bi, bound in enumerate(bounds):
            if s <= bound:
                assigned[bi].append(i)
                break
    buckets = [Bucket(pad_to=bound, indices=np.array(ix, dtype=np.int64))
               for bound, ix in zip(bounds, assigned) if len(ix)]
    return BucketedDataset(graphs=list(graphs), buckets=buckets,
                           multiple_of=multiple_of)


@dataclasses.dataclass(frozen=True)
class PairBlock:
    """A fixed-shape chunk of all-pairs work: the scheduling unit.

    rows/cols are dataset indices; the block computes every (row, col)
    combination as a flat batch of ``len(rows)`` pairs (rows and cols are
    pre-flattened — rows[k] pairs with cols[k]).
    """
    block_id: int
    bucket_row: int
    bucket_col: int
    rows: np.ndarray
    cols: np.ndarray
    pad_row: int
    pad_col: int

    @property
    def n_pairs(self) -> int:
        return len(self.rows)

    def cost(self) -> float:
        """Cost model for load balancing: Σ (n_i * n_j)^2 — the XMV work of
        one CG iteration (paper Sec. V-B's 'variation of graph size')."""
        return float(self.n_pairs) * (self.pad_row * self.pad_col) ** 2


def gram_tile_blocks(ds: BucketedDataset, tile_rows: int = 8,
                     tile_cols: int = 8,
                     upper_triangular: bool = True) -> Iterator[PairBlock]:
    """All-pairs work as RECTANGULAR Gram tiles (DESIGN.md §8).

    Unlike :func:`pair_blocks` — which chunks the raveled pair list, so
    a block's rows/cols are an arbitrary span of the product — every
    block here is the row-major flattening of ``unique_rows x
    unique_cols`` with at most ``tile_rows`` x ``tile_cols`` unique
    graphs per axis. That rectangle structure is what Gram-tile
    execution exploits: ONE row-panel pack per axis (Bi + Bj packs, not
    Bi*Bj), each row graph's panels reused across all its column
    partners in one ``xmv_gram_tile`` launch.

    On a diagonal bucket pair with ``upper_triangular``, tiles lying
    entirely below the diagonal are skipped; tiles straddling it keep
    their full rectangle (a few redundant mirror pairs — the classic
    tile-vs-triangle trade; the symmetric Gram assembly of
    ``distributed/checkpoint.py`` absorbs them).
    """
    bid = 0
    nb = len(ds.buckets)
    for bi in range(nb):
        for bj in range(bi, nb) if upper_triangular else range(nb):
            r_idx = ds.buckets[bi].indices
            c_idx = ds.buckets[bj].indices
            for r0 in range(0, len(r_idx), tile_rows):
                for c0 in range(0, len(c_idx), tile_cols):
                    if upper_triangular and bi == bj \
                            and c0 + tile_cols <= r0:
                        continue      # tile entirely below the diagonal
                    rch = r_idx[r0:r0 + tile_rows]
                    cch = c_idx[c0:c0 + tile_cols]
                    rr, cc = np.meshgrid(rch, cch, indexing="ij")
                    yield PairBlock(
                        block_id=bid,
                        bucket_row=bi, bucket_col=bj,
                        rows=rr.ravel(), cols=cc.ravel(),
                        pad_row=ds.buckets[bi].pad_to,
                        pad_col=ds.buckets[bj].pad_to)
                    bid += 1


def pair_blocks(ds: BucketedDataset, pairs_per_block: int = 64,
                upper_triangular: bool = True) -> Iterator[PairBlock]:
    """Enumerate all-pairs work as fixed-shape blocks."""
    bid = 0
    nb = len(ds.buckets)
    for bi in range(nb):
        for bj in range(bi, nb) if upper_triangular else range(nb):
            rows_idx = ds.buckets[bi].indices
            cols_idx = ds.buckets[bj].indices
            rr, cc = np.meshgrid(rows_idx, cols_idx, indexing="ij")
            rr, cc = rr.ravel(), cc.ravel()
            if upper_triangular and bi == bj:
                keep = rr <= cc
                rr, cc = rr[keep], cc[keep]
            for s in range(0, len(rr), pairs_per_block):
                yield PairBlock(
                    block_id=bid,
                    bucket_row=bi, bucket_col=bj,
                    rows=rr[s:s + pairs_per_block],
                    cols=cc[s:s + pairs_per_block],
                    pad_row=ds.buckets[bi].pad_to,
                    pad_col=ds.buckets[bj].pad_to)
                bid += 1
