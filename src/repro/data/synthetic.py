"""Synthetic graph generators (paper Sec. VI-A).

Newman–Watts–Strogatz small-world graphs and Barabási–Albert scale-free
graphs, implemented directly in numpy (no networkx dependency in the hot
path) with the paper's benchmark parameters as defaults:
NWS k=3, p=0.1; BA m=6; 160 graphs x 96 nodes.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph

__all__ = ["newman_watts_strogatz", "barabasi_albert",
           "make_synthetic_dataset"]


def _finish(adj: np.ndarray, rng: np.random.Generator, labeled: bool,
            n_vertex_labels: int, stop_prob: float) -> Graph:
    n = adj.shape[0]
    adj = np.maximum(adj, adj.T).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    if labeled:
        edge_labels = rng.uniform(0.0, 1.0, size=(n, n)).astype(np.float32)
        edge_labels = np.triu(edge_labels, 1)
        edge_labels = edge_labels + edge_labels.T
        edge_labels *= (adj != 0)
        vertex_labels = rng.integers(0, n_vertex_labels, size=n).astype(
            np.float32)
    else:
        edge_labels = np.zeros_like(adj)
        vertex_labels = np.zeros(n, np.float32)
    return Graph.create(adj, edge_labels, vertex_labels,
                        stop_prob=stop_prob)


def newman_watts_strogatz(n: int, k: int = 3, p: float = 0.1,
                          *, rng: np.random.Generator,
                          labeled: bool = True, n_vertex_labels: int = 8,
                          stop_prob: float = 0.05) -> Graph:
    """NWS small-world graph: ring lattice of degree 2k plus random
    shortcuts added with probability p per edge (no rewiring removals)."""
    adj = np.zeros((n, n), np.float32)
    for off in range(1, k + 1):
        idx = np.arange(n)
        adj[idx, (idx + off) % n] = 1.0
    # shortcut additions
    n_short = rng.binomial(n * k, p)
    for _ in range(int(n_short)):
        u, v = rng.integers(0, n, size=2)
        if u != v:
            adj[u, v] = 1.0
    return _finish(adj, rng, labeled, n_vertex_labels, stop_prob)


def barabasi_albert(n: int, m: int = 6, *, rng: np.random.Generator,
                    labeled: bool = True, n_vertex_labels: int = 8,
                    stop_prob: float = 0.05) -> Graph:
    """BA preferential-attachment scale-free graph."""
    if n <= m:
        raise ValueError("n must exceed m")
    adj = np.zeros((n, n), np.float32)
    # start from a clique of m+1 nodes
    adj[:m + 1, :m + 1] = 1.0
    np.fill_diagonal(adj, 0.0)
    degrees = adj.sum(1)
    for new in range(m + 1, n):
        probs = degrees[:new] / degrees[:new].sum()
        targets = rng.choice(new, size=m, replace=False, p=probs)
        adj[new, targets] = 1.0
        adj[targets, new] = 1.0
        degrees[targets] += 1
        degrees[new] = m
    return _finish(adj, rng, labeled, n_vertex_labels, stop_prob)


def make_synthetic_dataset(kind: str = "nws", n_graphs: int = 160,
                           n_nodes: int = 96, seed: int = 0,
                           labeled: bool = True,
                           stop_prob: float = 0.05) -> list[Graph]:
    """The paper's synthetic benchmark set: 160 graphs of 96 nodes."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_graphs):
        if kind == "nws":
            out.append(newman_watts_strogatz(
                n_nodes, k=3, p=0.1, rng=rng, labeled=labeled,
                stop_prob=stop_prob))
        elif kind == "ba":
            out.append(barabasi_albert(
                n_nodes, m=6, rng=rng, labeled=labeled,
                stop_prob=stop_prob))
        else:
            raise ValueError(f"unknown kind {kind!r}")
    return out
