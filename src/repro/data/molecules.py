"""Real-world dataset surrogates (paper Sec. VI-B).

The paper benchmarks on PDB-3k (protein 3D structures; edges between
spatially neighboring heavy atoms with smoothly decaying weights, labeled
by interatomic distance) and DrugBank (SMILES molecular graphs, sizes
1..551). Both originals require network access; this container is offline,
so we generate statistically faithful surrogates:

* :func:`make_pdb_like_dataset` — 3D point clouds laid down as
  self-avoiding backbone chains with side-chain scatter; edges from the
  paper's adjacency rule  w(r) = smooth cutoff, labels = distance. Node
  coordinates are kept so Morton reordering is exercised.
* :func:`make_drugbank_like_dataset` — chemistry-like sparse graphs with a
  long-tailed size distribution (1..~550, matching the paper's stated
  variance), tree-dominated with rings, few discrete bond labels and
  element-coded vertices.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph

__all__ = ["make_pdb_like_dataset", "make_drugbank_like_dataset",
           "pdb_like_graph", "drugbank_like_graph"]


def _smooth_cutoff(r: np.ndarray, r_cut: float) -> np.ndarray:
    """Paper's adjacency rule: weights smoothly decay to zero at r_cut.
    We use the Wendland C2 profile (DESIGN.md: same family the paper cites
    for compact kernels)."""
    x = np.clip(r / r_cut, 0.0, 1.0)
    w = (1.0 - x) ** 4 * (4.0 * x + 1.0)
    return np.where(r < r_cut, w, 0.0)


def pdb_like_graph(n_atoms: int, *, rng: np.random.Generator,
                   r_cut: float = 1.8, stop_prob: float = 0.05
                   ) -> tuple[Graph, np.ndarray]:
    """A protein-like 3D structure graph; returns (graph, coords)."""
    # backbone: correlated random walk in 3D with unit steps
    steps = rng.normal(size=(n_atoms, 3))
    # correlate directions for secondary-structure-like locality
    for i in range(1, n_atoms):
        steps[i] = 0.7 * steps[i - 1] + 0.3 * steps[i]
    steps /= np.linalg.norm(steps, axis=1, keepdims=True) + 1e-9
    coords = np.cumsum(steps, axis=0)
    # side-chain scatter
    coords += 0.25 * rng.normal(size=coords.shape)
    diff = coords[:, None, :] - coords[None, :, :]
    dist = np.sqrt((diff ** 2).sum(-1))
    adj = _smooth_cutoff(dist, r_cut).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    edge_labels = (dist / r_cut).astype(np.float32) * (adj != 0)
    vertex_labels = rng.integers(0, 4, size=n_atoms).astype(np.float32)
    g = Graph.create(adj, edge_labels, vertex_labels, stop_prob=stop_prob)
    return g, coords.astype(np.float32)


def drugbank_like_graph(n_atoms: int, *, rng: np.random.Generator,
                        stop_prob: float = 0.05) -> Graph:
    """A SMILES-like chemical graph: random tree + ring closures, discrete
    bond-order edge labels and element-code vertex labels."""
    adj = np.zeros((n_atoms, n_atoms), np.float32)
    lab = np.zeros((n_atoms, n_atoms), np.float32)
    # bond orders normalized to [0, 1] (triple = 1.0) so the SE edge
    # kernel's feature expansion stays in its accurate domain
    bond_orders = np.array([1.0, 1.5, 2.0, 3.0], np.float32) / 3.0
    bond_probs = np.array([0.70, 0.15, 0.12, 0.03])
    for i in range(1, n_atoms):
        # attach to a recent atom (chain-like) or a random earlier one
        j = i - 1 if rng.random() < 0.7 else int(rng.integers(0, i))
        order = rng.choice(bond_orders, p=bond_probs)
        adj[i, j] = adj[j, i] = 1.0
        lab[i, j] = lab[j, i] = order
    # ring closures: ~ one per 6 atoms
    for _ in range(max(0, n_atoms // 6)):
        u, v = rng.integers(0, n_atoms, size=2)
        if u != v and adj[u, v] == 0:
            adj[u, v] = adj[v, u] = 1.0
            lab[u, v] = lab[v, u] = 1.0
    vertex_labels = rng.choice(
        np.arange(8, dtype=np.float32),
        p=[0.45, 0.25, 0.12, 0.08, 0.04, 0.03, 0.02, 0.01],
        size=n_atoms)
    return Graph.create(adj, lab, vertex_labels, stop_prob=stop_prob)


def make_pdb_like_dataset(n_graphs: int = 64, min_atoms: int = 40,
                          max_atoms: int = 220, seed: int = 0
                          ) -> tuple[list[Graph], list[np.ndarray]]:
    rng = np.random.default_rng(seed)
    graphs, coords = [], []
    for _ in range(n_graphs):
        n = int(rng.integers(min_atoms, max_atoms + 1))
        g, c = pdb_like_graph(n, rng=rng)
        graphs.append(g)
        coords.append(c)
    return graphs, coords


def make_drugbank_like_dataset(n_graphs: int = 128, seed: int = 0,
                               max_atoms: int = 551) -> list[Graph]:
    """Long-tailed size distribution mimicking DrugBank's 1..551 range."""
    rng = np.random.default_rng(seed)
    graphs = []
    for _ in range(n_graphs):
        # log-normal tail, clipped; mode ~ 25 atoms
        n = int(np.clip(rng.lognormal(mean=3.3, sigma=0.7), 2, max_atoms))
        graphs.append(drugbank_like_graph(n, rng=rng))
    return graphs
