"""Distributed all-pairs Gram computation.

Two-level parallelism on the production mesh (DESIGN.md §4):

* the PAIR axis (embarrassingly parallel, paper Sec. V-B) shards over every
  non-"model" mesh axis — ("pod", "data") on the multi-pod mesh;
* the MODEL axis parallelizes *within* a pair by sharding graph-1's node
  dimension — the rows of the nm x nm product system. CG dot products then
  reduce over sharded rows; GSPMD inserts the all-reduces (this is the
  collective-bound regime the §Roofline table quantifies).

Fault tolerance: the driver walks a SchedulePlan, persists every finished
PairBlock to a ChunkStore (atomic, CRC, first-writer-wins) and on restart
recomputes only missing blocks. Elasticity: replan() on the remaining
blocks whenever the device count changes between rounds.

Self-healing (DESIGN.md §10.2): every block's solve is health-checked
against the per-pair PCG status flags (core/pcg.py), and an unhealthy
block walks a DEGRADATION LADDER — same-rung retries first (transient
faults recompute clean, preserving bitwise identity with a fault-free
run), then cumulative escalation kron→jacobi preconditioner, bf16→f32
packs, segmented→lockstep PCG, and finally the dense numpy reference
oracle per pair. Pairs still broken after the last rung are QUARANTINED:
dropped from the saved block, listed in the manifest record and in
``GramDriver.health`` — never a silent NaN in the Gram. Chunks whose CRC
fails on restore are quarantined-and-recomputed the same way, and
repeatedly failing buckets are deprioritized on replanning
(distributed/scheduler.py failures knob).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Iterable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.base_kernels import BaseKernel, Constant
from repro.core.graph import GraphBatch
from repro.core.mgk import MGKResult, mgk_pairs, mgk_pairs_sparse
from repro.core.pcg import PCG_BREAKDOWN, PCG_DIVERGENCE, PCG_MAX_ITER, \
    PCG_NONFINITE, PCG_RESTARTED, PCG_STAGNATION
from repro.data.loader import BucketedDataset, PairBlock, pair_blocks
from .checkpoint import ChunkStore
from .scheduler import SchedulePlan, make_plan, replan

logger = logging.getLogger(__name__)

# status bits that flag a pair's solve as UNHEALTHY for the degradation
# ladder: any detected anomaly, including a recovered restart — a
# restarted trajectory differs from the clean one, so the block is
# retried at the same rung to reproduce the fault-free result bit-for-
# bit. MAX_ITER alone is NOT here: a merely-slow pair is surfaced via
# the non-convergence summary, not escalated (escalating it would churn
# without a defect to heal).
_UNHEALTHY = (PCG_BREAKDOWN | PCG_NONFINITE | PCG_STAGNATION
              | PCG_DIVERGENCE | PCG_RESTARTED)

__all__ = ["gram_pair_step", "solve_pair_block", "GramDriver",
           "GraphPackCache", "pair_shardings"]


class GraphPackCache:
    """Per-graph row-panel pack cache for the all-pairs driver.

    A graph appears in O(N) pair blocks of the Gram matrix; without a
    cache it is octile-decomposed and repacked every time its bucket
    shows up (``row_panel_packs_for_batch`` per block). Here each graph
    is decomposed ONCE per (dataset index, pad_to) — keyed by dataset
    index, not array contents — and stored as host arrays at its natural
    slot count; per-block stacking is then a cheap pad-and-stack to the
    block's shared k_max.

    ``edge_kernel`` (feature-expandable) additionally precomputes the MXU
    contraction operands into the cached packs. ``max_entries`` bounds
    host memory with LRU eviction (configurable through
    ``GramDriver.pack_cache_entries``) — the scheduler emits blocks
    bucket-contiguously, so even a bound far below the dataset size keeps
    the reuse (a graph's blocks are temporally close). An evicted graph
    is simply re-decomposed on its next miss; the round trip is
    bit-identical (the pack is a pure function of the graph arrays).

    Pack-time STATISTICS (octile count, nnz, occupancy density) persist
    in ``self.stats`` even after the pack itself is evicted — they are a
    few floats per graph and feed the scheduler's cost model
    (``GramDriver.plan`` -> ``scheduler.estimate_cost``), replacing its
    uniform-density assumption with measured sparsity.

    ``pack_dtype`` stores the pack value buffers (``values_adj`` /
    ``values_lab`` / ``values_w`` / ``values_grad``) in a narrower
    dtype — ``jnp.bfloat16`` halves HBM bytes per matvec while the
    kernels keep f32 accumulators (DESIGN.md §9.4).

    Kronecker-preconditioner FACTORS (``core/precond.py``) are cached
    alongside the packs, keyed by the same (dataset index, pad):
    computed once per graph at pack time from its degree/adjacency
    statistics, stacked per pair batch (:meth:`stacked_factors`) or per
    Gram-tile axis (mirroring :meth:`stacked_axis`). A few O(n²) host
    arrays per graph; evicted and rebuilt with the packs.
    """

    def __init__(self, tile: int = 8, edge_kernel=None,
                 max_entries: int = 65536, with_grad: bool = False,
                 pack_dtype=None):
        import collections
        self.tile = tile
        self.edge_kernel = edge_kernel
        self.max_entries = max_entries
        self.with_grad = with_grad   # also bake values_grad companions
        self.pack_dtype = pack_dtype
        self._packs: "collections.OrderedDict" = collections.OrderedDict()
        self._factors: "collections.OrderedDict" = \
            collections.OrderedDict()
        self.stats: dict = {}        # (idx, pad) -> octile/nnz/density
        self.hits = 0
        self.misses = 0

    def _lru_get(self, store, key, build) -> dict:
        """Shared LRU lookup for the pack and factor stores: counts
        hits/misses (both stores feed the same counters), bounds each
        store at ``max_entries``, builds on miss."""
        hit = store.get(key)
        if hit is not None:
            self.hits += 1
            store.move_to_end(key)
            return hit
        self.misses += 1
        while len(store) >= self.max_entries:
            store.popitem(last=False)
        entry = build()
        store[key] = entry
        return entry

    def _pack(self, idx, adjacency, labels, pad_to) -> dict:
        key = (int(idx), int(pad_to))
        return self._lru_get(self._packs, key,
                             lambda: self._build_pack(key, adjacency,
                                                      labels))

    def _build_pack(self, key, adjacency, labels) -> dict:
        from repro.core.octile import octile_decompose
        from repro.kernels.xmv_block_sparse import pack_row_panels
        oset = octile_decompose(adjacency, labels, tile=self.tile)
        nt = oset.n_tiles_side
        self.stats[key] = {
            "octiles": int(oset.n_nonempty),
            "nnz": int(np.count_nonzero(oset.values_adj)),
            "tile_rows": int(nt),
            "density": float(oset.n_nonempty) / max(nt * nt, 1),
        }
        # as_numpy: the cache re-pads and stacks host-side; the single
        # device transfer happens in stacked()
        p = pack_row_panels(oset, edge_kernel=self.edge_kernel,
                            as_numpy=True, with_grad=self.with_grad,
                            pack_dtype=self.pack_dtype)
        return {f: getattr(p, f) for f in type(p)._fields}

    def _factor(self, idx, batch: GraphBatch, b: int, pad_to) -> dict:
        """Per-graph Kronecker-preconditioner factors, cached like the
        packs (host numpy at the graph's padded size; same LRU bound
        and hit/miss counters, in their own store)."""
        from repro.core.precond import KronFactors, kron_factor_arrays

        def build():
            f = kron_factor_arrays(
                np.asarray(batch.adjacency[b]),
                np.asarray(batch.degrees[b]),
                np.asarray(batch.edge_labels[b]),
                np.asarray(batch.vertex_labels[b]),
                np.asarray(batch.node_mask[b]))
            return {name: np.asarray(getattr(f, name))
                    for name in KronFactors._fields}

        return self._lru_get(self._factors, (int(idx), int(pad_to)),
                             build)

    def stacked_factors(self, indices, batch: GraphBatch):
        """Stacked :class:`~repro.core.precond.KronFactors` for one pair
        batch (or, called with the UNIQUE graphs of a Gram-tile axis,
        the per-axis factors — the factor analog of
        :meth:`stacked_axis`). Indexing contract as :meth:`stacked`:
        entries beyond ``len(indices)`` are dummy pairs (index -1)."""
        from repro.core.precond import KronFactors
        B = batch.adjacency.shape[0]
        pad_to = batch.adjacency.shape[1]
        entries = []
        for b in range(B):
            idx = int(indices[b]) if b < len(indices) else -1
            entries.append(self._factor(idx, batch, b, pad_to))
        return KronFactors(**{
            name: jnp.asarray(np.stack([e[name] for e in entries]))
            for name in KronFactors._fields})

    def density(self, idx: int, pad_to: int) -> float | None:
        """Measured octile occupancy of graph ``idx`` at bucket pad
        ``pad_to`` — None until the graph has been packed once."""
        s = self.stats.get((int(idx), int(pad_to)))
        return None if s is None else s["density"]

    @staticmethod
    def _pad_k(arr: np.ndarray, k_max: int) -> np.ndarray:
        k = arr.shape[1]
        if k == k_max:
            return arr
        pad = [(0, 0)] * arr.ndim
        pad[1] = (0, k_max - k)
        return np.pad(arr, pad)

    def stacked(self, indices, batch: GraphBatch):
        """Build the stacked RowPanelPack for one (padded) pair batch.

        ``indices[b]`` is the dataset index of ``batch`` entry b; entries
        beyond ``len(indices)`` are data-parallel dummy pairs (cached
        under index -1 — their adjacency is all zero)."""
        from repro.kernels.xmv_block_sparse import RowPanelPack
        B = batch.adjacency.shape[0]
        pad_to = batch.adjacency.shape[1]
        if pad_to % self.tile:
            raise ValueError(
                f"bucket padded to {pad_to}, not a multiple of"
                f" tile={self.tile}; pad buckets to a multiple of the"
                f" tile edge (loader multiple_of)")
        entries = []
        for b in range(B):
            idx = int(indices[b]) if b < len(indices) else -1
            entries.append(self._pack(idx, np.asarray(batch.adjacency[b]),
                                      np.asarray(batch.edge_labels[b]),
                                      pad_to))
        k_max = max(e["col"].shape[1] for e in entries)

        def stack(field):
            if entries[0][field] is None:
                return None
            if field == "count":
                return jnp.asarray(np.stack([e[field] for e in entries]))
            return jnp.asarray(np.stack(
                [self._pad_k(e[field], k_max) for e in entries]))

        return RowPanelPack(**{f: stack(f) for f in RowPanelPack._fields})

    def stacked_axis(self, indices, batch: GraphBatch):
        """PER-AXIS pack for Gram-tile execution (DESIGN.md §8): one
        stacked RowPanelPack over the given UNIQUE graphs — the Bi row
        (or Bj column) axis of an I x J Gram tile. Compared to building
        :meth:`stacked` per-pair packs for the tile's flattened pair
        batch, this skips the Bj-fold (resp. Bi-fold) re-stacking and
        device-transfer duplication entirely: each graph's panels are
        padded and shipped once per tile, and the Gram-tile kernel
        reuses them across every partner."""
        if batch.adjacency.shape[0] != len(indices):
            raise ValueError(
                f"axis batch size {batch.adjacency.shape[0]} != "
                f"{len(indices)} axis indices (per-axis packs take the"
                f" UNIQUE graphs, not the flattened pair batch)")
        return self.stacked(indices, batch)


def pair_shardings(mesh: Mesh) -> tuple:
    """(in_shardings for (g1, g2), out_shardings for MGKResult).

    g1's node dimension rides the "model" axis (rows of the product
    system); g2 is replicated over "model". The pair/batch axis shards over
    all remaining mesh axes.
    """
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    model = "model" if "model" in mesh.axis_names else None
    b = batch_axes if batch_axes else None

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    g1_shard = GraphBatch(
        adjacency=ns(b, model, None),
        edge_labels=ns(b, model, None),
        vertex_labels=ns(b, model),
        start_prob=ns(b, model),
        stop_prob=ns(b, model),
        degrees=ns(b, model),
        node_mask=ns(b, model),
        n_nodes=ns(b),
    )
    g2_shard = GraphBatch(
        adjacency=ns(b, None, None),
        edge_labels=ns(b, None, None),
        vertex_labels=ns(b, None),
        start_prob=ns(b, None),
        stop_prob=ns(b, None),
        degrees=ns(b, None),
        node_mask=ns(b, None),
        n_nodes=ns(b),
    )
    out_shard = MGKResult(values=ns(b), iterations=ns(b), converged=ns(b),
                          nodal=None, status=ns(b))
    return (g1_shard, g2_shard), out_shard


# per-grid-step VMEM envelope above which gram_pair_step routes a
# Gram-tile block back to the per-pair row-panel kernel (the ~16 MB/core
# budget minus headroom for Mosaic's own buffers)
_GRAM_TILE_VMEM_BUDGET = 12 << 20


def _axis_structure(rows, cols):
    """(unique_rows, unique_cols) if (rows, cols) is the row-major
    flattening of their rectangle (``gram_tile_blocks`` structure),
    else None (ragged blocks fall back to per-pair execution)."""
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    B = len(rows)
    if B == 0 or len(cols) != B:
        return None
    changes = np.nonzero(rows != rows[0])[0]
    Bj = int(changes[0]) if changes.size else B
    if B % Bj:
        return None
    Bi = B // Bj
    urows, ucols = rows[::Bj], cols[:Bj]
    if len(set(urows.tolist())) != Bi or len(set(ucols.tolist())) != Bj:
        return None
    if not (np.array_equal(np.repeat(urows, Bj), rows)
            and np.array_equal(np.tile(ucols, Bi), cols)):
        return None
    return urows, ucols


def gram_pair_step(mesh: Mesh, vertex_kernel: BaseKernel,
                   edge_kernel: BaseKernel, *, method: str = "lowrank",
                   tol: float = 1e-8, max_iter: int = 256,
                   fixed_iters: int | None = None,
                   pcg_variant: str = "classic",
                   sparse_mode: str = "auto",
                   tile: int = 8,
                   gram_tile: bool = False,
                   segment_size: int | None = None,
                   segment_pad: int = 1,
                   pack_cache_entries: int = 65536,
                   with_grad: bool = False,
                   precond: str = "jacobi",
                   kron_rank: int = 2,
                   pack_dtype=None,
                   guard=True) -> Callable:
    """Build the pair-solve step for a mesh.

    ``guard`` (GuardSpec | bool) enables the per-pair PCG numerical
    guards (core/pcg.py); results then carry the [B] ``status`` bitmask
    the driver's degradation ladder keys on. Every returned step also
    accepts per-call ``fault=``/``spd_margin=`` keywords — the
    deterministic injection seams (distributed/faults.py) — except the
    gradient steps, whose adjoint path has no injection seam (the
    ladder never injects into ``run_with_grad``).

    ``precond="kron"`` solves every block (forward and, under
    ``with_grad``, adjoint) with the Kronecker-factored approximate
    inverse (core/precond.py, DESIGN.md §9); on the sparse path the
    per-graph factors come from the SAME pack cache as the octile
    panels — computed once per (graph, bucket pad), stacked per pair
    block or per Gram-tile axis. ``pack_dtype=jnp.bfloat16`` streams
    the pack value buffers at half the HBM bytes per matvec (f32
    accumulation in-kernel, §9.4).

    ``with_grad=True`` builds a GRADIENT step instead: each pair block
    returns ``(MGKResult, {"vertex.h": [B], "edge.alpha": [B], ...})`` —
    the hyperparameter gradients ∂K/∂θ of every pair, computed by the
    adjoint-PCG custom VJP (core/adjoint.py) in the SAME pass (one
    forward + one adjoint solve per block; DESIGN.md §7). On the sparse
    path the pack cache bakes the ``values_w``/``values_grad`` operand
    buffers once per graph and both solves trust them
    (``trust_pack_weights``), so a graph is decomposed-and-weighted once
    per bucket size for the whole gradient Gram. Gradient steps run
    host-driven (pair-data-parallel over blocks, no "model" sharding).

    ``pcg_variant="pipelined"`` halves the per-iteration all-reduce rounds
    when the product rows are sharded over "model" (DESIGN.md §3/§4);
    ``fixed_iters`` makes every pair of a bucket run the same trip count
    (the paper's load-balancing premise, and a known-size scan for the
    static roofline).

    ``method="pallas_sparse"`` returns a host-driven step: the octile
    row-panel packs are per-graph index structures (not shardable
    tensors), served from a :class:`GraphPackCache` keyed by dataset
    index so each graph is decomposed once per bucket size instead of
    once per pair block; the whole bucket then solves in one row-panel
    kernel launch per CG matvec. ``sparse_mode`` "auto" uses the MXU
    contraction whenever ``edge_kernel`` has a feature expansion;
    ``tile`` sets the octile edge (buckets must pad to a multiple).
    The step accepts optional ``rows``/``cols`` dataset indices (the
    driver passes them; without them the packs are built uncached).

    ``gram_tile=True`` (sparse only): blocks whose (rows, cols) form a
    rectangle (``data.gram_tile_blocks``) solve in GRAM-TILE execution
    (DESIGN.md §8) — ONE row-panel pack per axis from
    :meth:`GraphPackCache.stacked_axis` (no per-pair restacking) and one
    ``xmv_gram_tile`` launch per matvec, reusing each row graph's
    panels across all its column partners. Non-rectangular blocks fall
    back to the per-pair path transparently.

    ``segment_size`` (sparse, forward only): solve with
    convergence-segmented PCG — converged pairs RETIRE between segments
    instead of riding along masked (``mgk_pairs_sparse_segmented``;
    ``segment_pad`` rounds live-batch sizes to bound jit-shape
    diversity). Mutually exclusive with ``fixed_iters``."""
    solve_kw = dict(tol=tol, max_iter=max_iter, fixed_iters=fixed_iters,
                    pcg_variant=pcg_variant)
    precond_kw = dict(precond=precond, kron_rank=kron_rank)
    if method == "pallas_sparse":
        from repro.core.mgk import mgk_pairs_sparse_segmented
        from repro.kernels.ops import row_panel_packs_for_batch

        if segment_size is not None and fixed_iters is not None:
            raise ValueError(
                "segment_size (convergence-segmented PCG) and"
                " fixed_iters (uniform trip count) are mutually"
                " exclusive")
        if segment_size is not None and with_grad:
            raise ValueError(
                "segment_size is forward-only: the adjoint custom_vjp"
                " (run_with_grad) solves with lockstep pcg_solve —"
                " unset segment_size for gradient runs")
        expand = edge_kernel.feature_rank() is not None and \
            sparse_mode in ("auto", "mxu")
        if sparse_mode == "mxu" and not expand:
            raise ValueError(
                f"sparse_mode='mxu' needs a feature-expandable edge"
                f" kernel, got {type(edge_kernel).__name__}")
        ek_pack = edge_kernel if expand else None
        mode = "mxu" if expand else "elementwise"
        # the expansion's accuracy domain (SE Taylor truncation): under
        # "auto", blocks whose labels leave it run exact elementwise —
        # same guard as mgk_adaptive; explicit "mxu" is honored as given
        domain = getattr(edge_kernel, "domain", None) \
            if sparse_mode == "auto" else None
        cache = GraphPackCache(tile=tile, edge_kernel=ek_pack,
                               max_entries=pack_cache_entries,
                               with_grad=with_grad,
                               pack_dtype=pack_dtype)

        def _resolve_block_mode(g1, g2):
            if mode == "mxu" and domain is not None:
                lmax = max(float(np.abs(np.asarray(g1.edge_labels)).max()),
                           float(np.abs(np.asarray(g2.edge_labels)).max()))
                if lmax > domain:
                    return "elementwise"
            return mode

        kron = precond == "kron"

        def _block_packs(g1, g2, rows, cols):
            """(packs1, packs2, mode, gram_tile_shape, factors) for one
            block: per-AXIS packs + (Bi, Bj) when the block is a
            rectangle and gram_tile execution is on, else per-pair
            packs + None. ``factors`` are the cached Kronecker
            preconditioner factors — stacked with the SAME granularity
            as the packs (per-axis / per-pair) — or (None, None) under
            Jacobi."""
            block_mode = _resolve_block_mode(g1, g2)
            axes = _axis_structure(rows, cols) \
                if gram_tile and rows is not None and cols is not None \
                else None
            if axes is not None:
                from repro.kernels.xmv_block_sparse import \
                    gram_tile_vmem_bytes
                urows, ucols = axes
                Bi, Bj = len(urows), len(ucols)
                # the flattened pair batch is urows x ucols row-major:
                # unique row graphs sit at strides of Bj, the unique
                # column graphs are the first Bj entries
                g1u = jax.tree.map(lambda x: x[::Bj], g1)
                g2u = jax.tree.map(lambda x: x[:Bj], g2)
                p1 = cache.stacked_axis(urows, g1u)
                p2 = cache.stacked_axis(ucols, g2u)
                # route buckets whose per-step envelope (graph j's whole
                # pack + the P panel) would crowd VMEM back to the
                # per-pair kernel, whose P BlockSpec streams instead
                if gram_tile_vmem_bytes(p1, p2, block_mode == "mxu") \
                        <= _GRAM_TILE_VMEM_BUDGET:
                    facs = (cache.stacked_factors(urows, g1u),
                            cache.stacked_factors(ucols, g2u)) \
                        if kron else (None, None)
                    return p1, p2, block_mode, (Bi, Bj), facs
            if rows is None or cols is None:
                p1 = row_panel_packs_for_batch(g1, tile=tile,
                                               edge_kernel=ek_pack,
                                               with_grad=with_grad)
                p2 = row_panel_packs_for_batch(g2, tile=tile,
                                               edge_kernel=ek_pack,
                                               with_grad=with_grad)
                facs = (None, None)   # uncached: factors derived in-trace
            else:
                p1 = cache.stacked(rows, g1)
                p2 = cache.stacked(cols, g2)
                facs = (cache.stacked_factors(rows, g1),
                        cache.stacked_factors(cols, g2)) \
                    if kron else (None, None)
            return p1, p2, block_mode, None, facs

        if with_grad:
            from repro.core.adjoint import flatten_grads, kernel_theta, \
                mgk_value_fn
            theta = kernel_theta(vertex_kernel, edge_kernel)

            def grad_sparse_step(g1, g2, rows=None, cols=None):
                p1, p2, block_mode, gt, facs = _block_packs(g1, g2,
                                                            rows, cols)
                fn = mgk_value_fn(g1, g2, vertex_kernel, edge_kernel,
                                  method="sparse", packs1=p1, packs2=p2,
                                  sparse_mode=block_mode,
                                  trust_pack_weights=True, gram_tile=gt,
                                  precond_factors=facs,
                                  **solve_kw, **precond_kw)
                vals, grads, sol = fn.value_and_pair_grads(theta,
                                                           with_aux=True)
                res = MGKResult(values=vals, iterations=sol.iterations,
                                converged=sol.converged, nodal=None,
                                status=sol.status)
                return res, flatten_grads(grads)

            grad_sparse_step.pack_cache = cache
            grad_sparse_step.wants_indices = True
            grad_sparse_step.no_pair_pad = gram_tile
            grad_sparse_step.with_grad = True
            return grad_sparse_step

        def sparse_step(g1: GraphBatch, g2: GraphBatch,
                        rows=None, cols=None, fault=None,
                        spd_margin=None) -> MGKResult:
            p1, p2, block_mode, gt, facs = _block_packs(g1, g2,
                                                        rows, cols)
            f1, f2 = facs
            if segment_size is not None:
                res = mgk_pairs_sparse_segmented(
                    g1, g2, p1, p2, vertex_kernel, edge_kernel,
                    sparse_mode=block_mode, tol=tol, max_iter=max_iter,
                    segment_size=segment_size, pad_multiple=segment_pad,
                    pcg_variant=pcg_variant, gram_tile=gt,
                    factors1=f1, factors2=f2, guard=guard, fault=fault,
                    spd_margin=spd_margin, **precond_kw)
            else:
                res = mgk_pairs_sparse(g1, g2, p1, p2, vertex_kernel,
                                       edge_kernel,
                                       sparse_mode=block_mode,
                                       gram_tile=gt, factors1=f1,
                                       factors2=f2, guard=guard,
                                       fault=fault,
                                       spd_margin=spd_margin,
                                       **solve_kw, **precond_kw)
            return MGKResult(values=res.values, iterations=res.iterations,
                             converged=res.converged, nodal=None,
                             matvec_pairs=res.matvec_pairs,
                             status=res.status)

        sparse_step.pack_cache = cache
        sparse_step.wants_indices = True
        sparse_step.no_pair_pad = gram_tile
        return sparse_step

    if with_grad:
        from repro.core.adjoint import flatten_grads, kernel_theta, \
            mgk_value_fn
        theta = kernel_theta(vertex_kernel, edge_kernel)

        def grad_step(g1: GraphBatch, g2: GraphBatch):
            fn = mgk_value_fn(g1, g2, vertex_kernel, edge_kernel,
                              method=method, **solve_kw, **precond_kw)
            vals, grads, sol = fn.value_and_pair_grads(theta,
                                                       with_aux=True)
            res = MGKResult(values=vals, iterations=sol.iterations,
                            converged=sol.converged, nodal=None,
                            status=sol.status)
            return res, flatten_grads(grads)

        grad_step.with_grad = True
        return grad_step

    (g1_s, g2_s), out_s = pair_shardings(mesh)

    def step(g1: GraphBatch, g2: GraphBatch) -> MGKResult:
        res = mgk_pairs(g1, g2, vertex_kernel, edge_kernel, method=method,
                        guard=guard, **solve_kw, **precond_kw)
        return MGKResult(values=res.values, iterations=res.iterations,
                         converged=res.converged, nodal=None,
                         status=res.status)

    jstep = jax.jit(step, in_shardings=(g1_s, g2_s), out_shardings=out_s)

    def dense_step(g1: GraphBatch, g2: GraphBatch, fault=None,
                   spd_margin=None) -> MGKResult:
        # clean calls take the jitted sharded step (one trace for the
        # whole build); an injected call routes around it — faults are
        # static jit arguments, so threading them through jstep would
        # retrace per distinct fault AND leak the fault into the cached
        # clean trace's key space
        if fault is None and spd_margin is None:
            return jstep(g1, g2)
        res = mgk_pairs(g1, g2, vertex_kernel, edge_kernel, method=method,
                        guard=guard, fault=fault, spd_margin=spd_margin,
                        **solve_kw, **precond_kw)
        return MGKResult(values=res.values, iterations=res.iterations,
                         converged=res.converged, nodal=None,
                         status=res.status)

    return dense_step


def _pad_batch(gb: GraphBatch, to: int) -> GraphBatch:
    """Pad the pair axis to a multiple of the data-parallel width with
    self-decoupled dummy pairs (mask 0, degree 1)."""
    B = gb.adjacency.shape[0]
    if B == to:
        return gb
    pad = to - B

    def pad_leaf(x, fill=0.0):
        shape = (pad,) + x.shape[1:]
        return jnp.concatenate([x, jnp.full(shape, fill, x.dtype)])

    return GraphBatch(
        adjacency=pad_leaf(gb.adjacency),
        edge_labels=pad_leaf(gb.edge_labels),
        vertex_labels=pad_leaf(gb.vertex_labels),
        start_prob=pad_leaf(gb.start_prob),
        stop_prob=pad_leaf(gb.stop_prob),
        degrees=pad_leaf(gb.degrees, 1.0),
        node_mask=pad_leaf(gb.node_mask),
        n_nodes=pad_leaf(gb.n_nodes),
    )


def solve_pair_block(ds: BucketedDataset, block: PairBlock, step: Callable,
                     pair_width: int, fault=None,
                     spd_margin=None) -> dict[str, np.ndarray]:
    """Run one PairBlock through the sharded step; returns host arrays.

    ``fault``/``spd_margin`` forward to the step's injection seams
    (only passed when set — gradient steps don't take them)."""
    g1 = ds.batch(block.rows, pad_to=block.pad_row)
    g2 = ds.batch(block.cols, pad_to=block.pad_col)
    B = block.n_pairs
    # Gram-tile steps keep the exact Bi x Bj rectangle (host-driven, no
    # pair-axis sharding to pad for — dummy pairs would break it)
    to = B if getattr(step, "no_pair_pad", False) \
        else -(-B // pair_width) * pair_width
    kw = {}
    if fault is not None:
        kw["fault"] = fault
    if spd_margin is not None:
        kw["spd_margin"] = spd_margin
    if getattr(step, "wants_indices", False):
        # pack-caching sparse step: keyed by dataset index (dummy pairs
        # appended by _pad_batch key as -1 inside the cache)
        res = step(_pad_batch(g1, to), _pad_batch(g2, to),
                   rows=block.rows, cols=block.cols, **kw)
    else:
        res = step(_pad_batch(g1, to), _pad_batch(g2, to), **kw)
    grads = None
    if getattr(step, "with_grad", False):
        res, grads = res
    out = {
        "rows": np.asarray(block.rows),
        "cols": np.asarray(block.cols),
        "values": np.asarray(res.values)[:B],
        "iterations": np.asarray(res.iterations)[:B],
    }
    if res.status is not None:
        out["status"] = np.asarray(res.status)[:B]
    if grads is not None:
        # ∂K/∂θ blocks ride along as extra arrays, one per flat key
        out.update({f"grad_{k}": np.asarray(v)[:B]
                    for k, v in grads.items()})
    return out


@dataclasses.dataclass
class GramDriver:
    """End-to-end fault-tolerant all-pairs driver.

    Usage:
        driver = GramDriver(ds, mesh, vertex_kernel, edge_kernel, store)
        gram = driver.run()            # resumable; returns [N, N] matrix

    ``gram_tile=True`` (with ``method="pallas_sparse"``) switches block
    generation to rectangular ``tile_shape`` Gram tiles and the solve to
    Gram-tile execution (per-axis packs + ``xmv_gram_tile``, DESIGN.md
    §8); ``segment_size`` additionally retires converged pairs between
    PCG segments (forward ``run()`` only — ``run_with_grad`` raises,
    its adjoint custom_vjp solves lockstep). ``plan()`` feeds MEASURED
    sparsity (pack-cache octile
    stats) and observed per-pair CG iteration counts (finished blocks in
    the store) back into the scheduler's cost model.

    SELF-HEALING (module docstring; DESIGN.md §10.2): with ``guard``
    on (default), each block's per-pair PCG status is health-checked and
    an unhealthy block walks :meth:`_ladder` — ``max_block_retries``
    same-rung retries, then cumulative escalation down to the dense
    reference oracle; pairs broken on the last rung are quarantined
    (dropped from the block, recorded in the manifest ``meta`` and in
    ``self.health``). ``faults`` takes a
    :class:`~repro.distributed.faults.FaultInjector` whose hooks the
    driver calls at the two seams (solve-time, post-save) — None in
    production. After a run, ``self.health`` holds retry/escalation
    counters, the quarantined (i, j) list, a per-block recovery trail,
    and the per-bucket count of pairs that hit max_iter without
    reaching tol (also journaled via ``store.note`` and logged).
    """
    ds: BucketedDataset
    mesh: Mesh
    vertex_kernel: BaseKernel = Constant(1.0)
    edge_kernel: BaseKernel = Constant(1.0)
    store: ChunkStore | None = None
    method: str = "lowrank"
    tol: float = 1e-8
    max_iter: int = 256
    fixed_iters: int | None = None
    pcg_variant: str = "classic"
    sparse_mode: str = "auto"     # pallas_sparse: "auto" | "mxu" | ...
    tile: int = 8                 # octile edge for the sparse path
    pairs_per_block: int = 64
    gram_tile: bool = False       # Gram-tile execution (sparse only)
    tile_shape: tuple[int, int] = (8, 8)   # unique graphs per tile axis
    segment_size: int | None = None        # segmented PCG (sparse only)
    segment_pad: int = 1
    pack_cache_entries: int = 65536        # GraphPackCache LRU bound
    precond: str = "jacobi"                # "jacobi" | "kron" (§9)
    kron_rank: int = 2                     # Kronecker terms, 1 or 2
    pack_dtype: object = None              # e.g. jnp.bfloat16 (§9.4)
    normalize: bool = True
    guard: object = True                   # GuardSpec | bool (§10.1)
    faults: object = None                  # FaultInjector | None (§10.4)
    max_block_retries: int = 1             # same-rung retries per rung

    def __post_init__(self):
        self._pack_cache = None   # set by _run (the step's cache)
        self._iter_stats: dict[int, float] = {}  # block id -> mean iters
        self._step_cache: dict = {}   # (with_grad, overrides) -> step
        self._block_failures: dict[int, int] = {}
        self.health: dict = self._fresh_health()
        if self.gram_tile and self.method != "pallas_sparse":
            raise ValueError(
                "gram_tile execution needs method='pallas_sparse'")

    @staticmethod
    def _fresh_health() -> dict:
        return {"retries": 0, "escalations": 0, "quarantined_pairs": [],
                "blocks": {}, "nonconverged_by_bucket": {}}

    def blocks(self) -> list[PairBlock]:
        if self.gram_tile:
            from repro.data.loader import gram_tile_blocks
            return list(gram_tile_blocks(self.ds, *self.tile_shape))
        return list(pair_blocks(self.ds, self.pairs_per_block))

    def plan(self, blocks: list[PairBlock] | None = None) -> SchedulePlan:
        blocks = blocks if blocks is not None else self.blocks()
        done = self.store.done_blocks() if self.store else set()
        n_groups = max(
            1, self.mesh.devices.size // self._pair_width())
        return replan(blocks, done, n_groups,
                      densities=self._block_densities(blocks),
                      iters=self._block_iters(blocks, done),
                      precond=self.precond,
                      failures=self._failure_map(blocks))

    def _failure_map(self, blocks) -> dict[int, int] | None:
        """Observed solve-failure counts expanded BUCKET-wise for the
        scheduler: a failing pair usually indicts its bucket's
        conditioning (graph sizes / label distribution), so every block
        of that bucket pair is deprioritized, direct failures keeping
        their own (higher) counts."""
        if not self._block_failures:
            return None
        by_id = {b.block_id: b for b in blocks}
        by_bucket: dict[tuple, int] = {}
        for bid, cnt in self._block_failures.items():
            blk = by_id.get(bid)
            if blk is not None:
                key = (blk.bucket_row, blk.bucket_col)
                by_bucket[key] = max(by_bucket.get(key, 0), cnt)
        out = {}
        for b in blocks:
            cnt = by_bucket.get((b.bucket_row, b.bucket_col), 0)
            cnt = max(cnt, self._block_failures.get(b.block_id, 0))
            if cnt:
                out[b.block_id] = cnt
        return out or None

    def _block_densities(self, blocks) -> dict[int, float] | None:
        """Measured per-block octile occupancy from the pack cache's
        stats (scheduler satellite): the product system touches
        d_row * d_col of the tile products, and estimate_cost squares
        its density knob, so the block estimate is sqrt(d_r * d_c)."""
        cache = self._pack_cache
        if cache is None or not cache.stats:
            return None
        out = {}
        for b in blocks:
            dr = [cache.density(int(i), b.pad_row)
                  for i in set(b.rows.tolist())]
            dc = [cache.density(int(i), b.pad_col)
                  for i in set(b.cols.tolist())]
            dr = [d for d in dr if d is not None]
            dc = [d for d in dc if d is not None]
            if dr and dc:
                out[b.block_id] = float(
                    np.sqrt(np.mean(dr) * np.mean(dc)))
        return out or None

    def _block_iters(self, blocks, done) -> dict[int, float] | None:
        """Predicted CG iterations per block from OBSERVED per-pair
        iteration counts of finished blocks (PCGResult.iterations
        persisted in the store), averaged per bucket pair — the paper's
        'iteration count varies with sparsity pattern' feedback loop."""
        if not self.store or not done:
            return None
        by_id = {b.block_id: b for b in blocks}
        per_bucket: dict = {}
        for bid in done:
            blk = by_id.get(bid)
            if blk is None:
                continue
            # memoized per block: a finished block's record is
            # immutable, so each npz is read (and CRC-checked) at most
            # once per driver even across repeated plan()/replan calls
            mean_it = self._iter_stats.get(bid)
            if mean_it is None:
                # planning must survive a corrupt chunk: quarantine it
                # (the run loop recomputes) instead of aborting the plan
                rec = self.store.load_block(bid, on_error="quarantine")
                if rec is None or len(rec["iterations"]) == 0:
                    continue
                mean_it = float(np.mean(rec["iterations"]))
                self._iter_stats[bid] = mean_it
            per_bucket.setdefault(
                (blk.bucket_row, blk.bucket_col), []).append(mean_it)
        if not per_bucket:
            return None
        mean = {k: float(np.mean(v)) for k, v in per_bucket.items()}
        return {b.block_id: mean[(b.bucket_row, b.bucket_col)]
                for b in blocks
                if (b.bucket_row, b.bucket_col) in mean} or None

    def _pair_width(self) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        w = 1
        for a, s in sizes.items():
            if a != "model":
                w *= s
        return w

    # -- degradation ladder (DESIGN.md §10.2) -----------------------------
    def _ladder(self, with_grad: bool) -> list[tuple[str, dict | None]]:
        """Ordered (name, CUMULATIVE overrides) rungs; ``None`` overrides
        = the dense numpy reference oracle. Rungs only exist for features
        the driver actually uses (a jacobi/f32/lockstep build starts at
        its own floor). ``run_with_grad`` stops before the oracle — the
        reference path has no hyperparameter gradients, and a gradient
        Gram with silently missing ∂K/∂θ entries would be worse than a
        quarantined pair."""
        rungs: list[tuple[str, dict | None]] = [("base", {})]
        cum: dict = {}
        if self.precond != "jacobi":
            cum = dict(cum, precond="jacobi")
            rungs.append(("jacobi-precond", dict(cum)))
        if self.pack_dtype is not None:
            cum = dict(cum, pack_dtype=None)
            rungs.append(("f32-packs", dict(cum)))
        if self.segment_size is not None and not with_grad:
            cum = dict(cum, segment_size=None)
            rungs.append(("lockstep-pcg", dict(cum)))
        if not with_grad:
            rungs.append(("reference", None))
        return rungs

    def _build_step(self, with_grad: bool, overrides: dict) -> Callable:
        """The pair-solve step for one ladder rung, cached per
        (with_grad, overrides) — rung steps (and their jit traces /
        pack caches) build once per driver, not once per sick block."""
        key = (with_grad, tuple(sorted(overrides.items())))
        step = self._step_cache.get(key)
        if step is None:
            cfg = dict(method=self.method, tol=self.tol,
                       max_iter=self.max_iter,
                       fixed_iters=self.fixed_iters,
                       pcg_variant=self.pcg_variant,
                       sparse_mode=self.sparse_mode, tile=self.tile,
                       gram_tile=self.gram_tile,
                       segment_size=self.segment_size,
                       segment_pad=self.segment_pad,
                       pack_cache_entries=self.pack_cache_entries,
                       with_grad=with_grad, precond=self.precond,
                       kron_rank=self.kron_rank,
                       pack_dtype=self.pack_dtype, guard=self.guard)
            cfg.update(overrides)
            step = gram_pair_step(self.mesh, self.vertex_kernel,
                                  self.edge_kernel, **cfg)
            self._step_cache[key] = step
        return step

    @staticmethod
    def _bad_pairs(out: dict) -> np.ndarray:
        """[B] bool: pairs whose solve is unhealthy — non-finite value,
        or any _UNHEALTHY status bit (guards tripped / restart taken)."""
        bad = ~np.isfinite(np.asarray(out["values"], np.float64))
        status = out.get("status")
        if status is not None:
            bad |= (np.asarray(status) & _UNHEALTHY) != 0
        return bad

    def _reference_block(self, block: PairBlock) -> dict:
        """Final ladder rung: the dense numpy direct solve
        (core/reference.py) pair by pair — no Pallas, no PCG, no
        preconditioner; slow but assumption-free."""
        from repro.core.reference import mgk_direct
        rows = np.asarray(block.rows)
        cols = np.asarray(block.cols)
        vals = np.empty(len(rows), np.float64)
        for k, (r, c) in enumerate(zip(rows, cols)):
            try:
                vals[k] = mgk_direct(self.ds.graphs[int(r)],
                                     self.ds.graphs[int(c)],
                                     self.vertex_kernel, self.edge_kernel)
            except np.linalg.LinAlgError:
                vals[k] = np.nan    # truly singular pair -> quarantine
        return {"rows": rows, "cols": cols, "values": vals,
                "iterations": np.zeros(len(rows), np.int32),
                "status": np.zeros(len(rows), np.int32)}

    def _solve_block_healed(self, block: PairBlock, with_grad: bool,
                            width: int) -> tuple[dict, dict | None]:
        """Solve one block through the degradation ladder.

        Returns ``(out, meta)``: the (possibly pair-filtered) block
        arrays and a JSON-serializable health record for the manifest —
        None when the first attempt came back clean (the ~always case).
        A transient fault is healed by the same-rung retry recomputing
        the block on a clean trajectory, so the saved arrays are
        BITWISE-IDENTICAL to a fault-free run's; only escalation (a
        persistent defect) changes numerics, and only quarantine drops
        pairs — both recorded, never silent."""
        bid = block.block_id
        inj = self.faults if (self.faults is not None
                              and not with_grad) else None
        trail: list[dict] = []
        attempt = 0
        out = None
        for rung_idx, (rung_name, overrides) in enumerate(
                self._ladder(with_grad)):
            if rung_idx > 0:
                self.health["escalations"] += 1
            # the oracle is deterministic — retrying it verbatim is pure
            # waste, so it gets exactly one attempt
            tries = 1 if overrides is None else self.max_block_retries + 1
            for retry in range(tries):
                if retry > 0:
                    self.health["retries"] += 1
                if overrides is None:
                    out = self._reference_block(block)
                else:
                    step = self._build_step(with_grad, overrides)
                    fault = inj.block_fault(bid, attempt) if inj else None
                    margin = inj.block_spd_margin(
                        bid, attempt,
                        overrides.get("precond", self.precond)) \
                        if inj else None
                    out = solve_pair_block(self.ds, block, step, width,
                                           fault=fault, spd_margin=margin)
                attempt += 1
                bad = self._bad_pairs(out)
                if not bad.any():
                    meta = {"recovery": trail} if trail else None
                    return out, meta
                trail.append({"rung": rung_name, "attempt": attempt - 1,
                              "bad_pairs": int(bad.sum())})
                self._block_failures[bid] = \
                    self._block_failures.get(bid, 0) + 1
        # ladder exhausted: quarantine the poison pairs — exclude them
        # from the block (and hence the Gram) and account for every one
        bad = self._bad_pairs(out)
        keep = ~bad
        qpairs = [[int(r), int(c)] for r, c
                  in zip(np.asarray(out["rows"])[bad],
                         np.asarray(out["cols"])[bad])]
        out = {k: np.asarray(v)[keep] for k, v in out.items()}
        self.health["quarantined_pairs"].extend(qpairs)
        logger.warning(
            "block %d: quarantined %d pair(s) after exhausting the "
            "degradation ladder: %s", bid, len(qpairs), qpairs)
        return out, {"recovery": trail, "quarantined_pairs": qpairs}

    def _nonconvergence_summary(self, results: dict[int, dict],
                                by_id: dict) -> None:
        """Tally pairs that ran to max_iter without reaching tol
        (PCG_MAX_ITER without a guard cause — slow, not sick) per bucket
        pair; surface via health, log, and the manifest journal.
        Satellite of DESIGN.md §10: slow convergence must be VISIBLE
        (it skews the cost model and hints at conditioning trouble) but
        is not escalated — the values are finite and sane."""
        per_bucket: dict[str, int] = {}
        for bid, rec in results.items():
            status = rec.get("status")
            if status is None:
                continue
            n_slow = int(((np.asarray(status) & PCG_MAX_ITER) != 0).sum())
            if not n_slow:
                continue
            blk = by_id.get(bid)
            key = f"{blk.bucket_row}x{blk.bucket_col}" if blk is not None \
                else f"block{bid}"
            per_bucket[key] = per_bucket.get(key, 0) + n_slow
        if not per_bucket:
            return
        self.health["nonconverged_by_bucket"] = per_bucket
        logger.warning(
            "%d pair(s) hit max_iter=%d without reaching tol=%g "
            "(per bucket pair: %s) — consider raising max_iter or "
            "loosening tol for these buckets",
            sum(per_bucket.values()), self.max_iter, self.tol,
            per_bucket)
        if self.store:
            self.store.note(kind="nonconvergence", buckets=per_bucket,
                            max_iter=int(self.max_iter),
                            tol=float(self.tol))

    def run(self, progress: Callable[[int, int], None] | None = None
            ) -> np.ndarray:
        return self._run(progress, with_grad=False)[0]

    def run_with_grad(
        self, progress: Callable[[int, int], None] | None = None
    ) -> tuple[np.ndarray, dict[str, np.ndarray]]:
        """Compute the Gram matrix AND its hyperparameter gradient blocks
        ``{"vertex.h": [N,N], "edge.alpha": [N,N], ...}`` in one pass
        (one forward + one adjoint PCG solve per pair block; the sparse
        pack cache is shared between both solves). With
        ``normalize=True`` the gradients are of the NORMALIZED Gram
        K̂_ij = K_ij / sqrt(K_ii K_jj):

            ∂K̂_ij = ∂K_ij / sqrt(K_ii K_jj)
                    - K̂_ij (∂K_ii / K_ii + ∂K_jj / K_jj) / 2
        """
        return self._run(progress, with_grad=True)

    def _run(self, progress, with_grad: bool):
        self.health = self._fresh_health()
        step = self._build_step(with_grad, {})
        self._pack_cache = getattr(step, "pack_cache", None)
        blocks = self.blocks()
        by_id = {b.block_id: b for b in blocks}
        done = self.store.done_blocks() if self.store else set()
        todo = [b.block_id for b in blocks if b.block_id not in done]
        width = self._pair_width()
        results: dict[int, dict] = {}
        pending = list(todo)
        n_done = 0
        while pending:
            bid = pending.pop(0)
            out, meta = self._solve_block_healed(by_id[bid], with_grad,
                                                 width)
            if meta:
                self.health["blocks"][bid] = meta
            if self.store:
                self.store.save_block(bid, meta=meta, **out)
                if self.faults is not None:
                    # injection seam: may corrupt the chunk on disk
                    # and/or raise DriverKilled (mid-build crash)
                    self.faults.after_block_saved(self.store, bid)
            else:
                results[bid] = out
            n_done += 1
            if progress:
                progress(n_done, len(todo))
            if meta and pending and self._block_failures.get(bid):
                # deprioritize blocks sharing a failing bucket pair so
                # healthy work lands first (mirrors plan()'s failures
                # feedback for the in-order walk)
                fmap = self._failure_map(
                    [by_id[b] for b in pending]) or {}
                pending.sort(key=lambda b: fmap.get(b, 0))
        n = len(self.ds)
        if self.store:
            # restore every completed block, quarantining (instead of
            # aborting on) chunks whose CRC no longer matches — then
            # recompute exactly the quarantined/missing ones. The
            # recompute saves WITHOUT the after_block_saved fault seam:
            # a deterministic corruption fault would otherwise re-abuse
            # the same block forever.
            results = {}
            for bid in sorted(self.store.done_blocks()):
                rec = self.store.load_block(bid, on_error="quarantine")
                if rec is not None:
                    results[bid] = dict(rec)
            missing = [b.block_id for b in blocks
                       if b.block_id not in results]
            for bid in missing:
                out, meta = self._solve_block_healed(by_id[bid],
                                                     with_grad, width)
                if meta:
                    self.health["blocks"][bid] = meta
                self.store.save_block(bid, meta=meta, **out)
                results[bid] = out
        if with_grad:
            # a store populated by a plain run() has value-only blocks;
            # recompute those in memory (save_block is first-writer-wins,
            # so the store keeps its value-only records) instead of
            # silently assembling empty/partial gradients
            want = [f"grad_vertex.{p}" for p in
                    self.vertex_kernel.param_names()] + \
                   [f"grad_edge.{p}" for p in
                    self.edge_kernel.param_names()]
            for bid, out in list(results.items()):
                if any(k not in out for k in want):
                    if bid not in by_id:
                        raise ValueError(
                            f"store block {bid} lacks gradient arrays and"
                            f" is not part of the current block plan"
                            f" (pairs_per_block changed?) — rerun with the"
                            f" original pairs_per_block or a fresh store")
                    results[bid], _ = self._solve_block_healed(
                        by_id[bid], with_grad, width)

        self._nonconvergence_summary(results, by_id)

        from .checkpoint import assemble_blocks

        # quarantined pairs leave NaN holes by design: loud (health
        # record, manifest, warning) but not fatal — downstream can mask
        # them via np.isnan. With nothing quarantined, a hole is a BUG
        # and assemble_blocks raises.
        strict = not self.health["quarantined_pairs"]

        def assemble(key):
            return assemble_blocks(results.values(), n, key,
                                   strict=strict)

        K = assemble("values")
        grads = None
        if with_grad:
            keys = [k for k in next(iter(results.values()))
                    if k.startswith("grad_")]
            grads = {k[len("grad_"):]: assemble(k) for k in keys}
        if self.normalize:
            d = np.sqrt(np.diag(K))
            Kn = K / d[:, None] / d[None, :]
            if grads is not None:
                grads = {
                    name: (g / d[:, None] / d[None, :]
                           - 0.5 * Kn * (np.diag(g) / np.diag(K))[:, None]
                           - 0.5 * Kn * (np.diag(g) / np.diag(K))[None, :])
                    for name, g in grads.items()}
            K = Kn
        return K, grads
