"""Deterministic fault injection for the Gram pipeline (DESIGN.md §10).

The robustness machinery of this codebase — PCG guards (core/pcg.py),
the degradation ladder (distributed/gram.py), the journaled ChunkStore
(distributed/checkpoint.py) — exists for failure modes that are
certainties at ~5·10⁹ pair solves but essentially unobservable in a
test-sized run. This module makes them OBSERVABLE AND REPEATABLE: a
:class:`FaultPlan` is a pure description of a fault campaign, every
per-block decision is a hash of ``(seed, block_id, salt)`` — NOT of
visit order — so the exact same blocks fail in the exact same way
across driver restarts, reruns, and machines. Tests and
``benchmarks/faults_bench.py`` drive a full Gram build through the
campaign and assert the end state: bitwise-identical to a fault-free
build, with every intervention accounted for in the manifest.

Fault classes (the §10.1 failure model, one knob each):

* **driver kill** — :class:`DriverKilled` raised after N completed
  blocks; the campaign runner restarts the driver against the same
  store (crash mid-build; exercises journal replay + only-missing
  recompute).
* **chunk corruption / truncation** — completed block files are
  bit-flipped or truncated ON DISK after a successful save (bit rot,
  torn copy; exercises CRC quarantine-and-recompute on restore).
* **matvec NaN** — a :class:`~repro.core.pcg.MatvecFault` corrupts the
  solver's matvec output for chosen pairs during a chosen iteration
  window, FIRST attempt of a block only (transient kernel fault;
  exercises the per-pair guards + same-rung retry, which recomputes the
  block on a clean trajectory — hence bitwise identity survives).
* **certificate failure** — the kron preconditioner's SPD margin is
  forced negative (``core/precond.py:kron_scalars``) on the first
  attempt, making ``M⁻¹`` indefinite (adversarial label distribution;
  exercises breakdown detection and the kron→jacobi ladder rung for
  persistent variants).

Faults are injected ONLY through public argument seams (``fault=``,
``spd_margin=``, bytes on disk) — never by monkeypatching module
internals, which jit trace-caching would silently ignore.
"""
from __future__ import annotations

import dataclasses
import os
import zlib

from repro.core.pcg import MatvecFault

__all__ = ["DriverKilled", "FaultPlan", "FaultInjector", "run_campaign"]


class DriverKilled(RuntimeError):
    """Simulated hard crash of the Gram driver (mid-build kill). Raised
    AFTER a block's save completes — the acutest spot: the store holds
    the block, the driver never got to act on it."""


def _hash01(seed: int, *keys) -> float:
    """Deterministic uniform [0, 1) from (seed, keys) — crc32 of the
    repr bytes. Stable across processes/hosts (unlike ``hash``) and
    independent of visit order (unlike a stateful RNG), which is what
    lets a restarted driver see the identical fault pattern."""
    payload = repr((seed,) + keys).encode()
    return zlib.crc32(payload) / 2**32


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative, seeded fault campaign. All fractions are per-block
    probabilities evaluated by :func:`_hash01` on (seed, block_id)."""
    seed: int = 0
    # raise DriverKilled after this many block saves in one driver run
    # (None = never). The campaign runner disarms it after it fires, so
    # one plan = one kill unless the caller re-arms.
    kill_after_blocks: int | None = None
    # fraction of completed chunk files to bit-flip / truncate on disk
    corrupt_fraction: float = 0.0
    truncate_fraction: float = 0.0
    # fraction of blocks whose FIRST solve attempt sees a matvec fault
    matvec_nan_fraction: float = 0.0
    matvec_nan_pairs: int = 1           # batch lanes hit per faulted block
    matvec_nan_value: float = float("nan")
    fault_start: int = 2                # iteration window of the fault
    fault_stop: int = 3
    # fraction of blocks whose FIRST attempt runs with a forced-negative
    # SPD margin (kron preconditioner certificate failure)
    cert_fail_fraction: float = 0.0
    cert_margin: float = -2.0           # |margin| >= 1 => indefinite M^-1
    # attempts the injection applies to: attempt < transient_attempts.
    # 1 (default) = transient (first attempt only — same-rung retry is
    # clean, preserving bitwise identity); a large value makes the fault
    # persistent, forcing ladder ESCALATION instead of retry recovery.
    transient_attempts: int = 1


class FaultInjector:
    """Runtime arm of a :class:`FaultPlan`, threaded into
    :class:`~repro.distributed.gram.GramDriver` (``faults=``).

    The driver calls three hooks; each is deterministic in
    (plan.seed, block_id) so restarts replay identically:

    * :meth:`block_fault` / :meth:`block_spd_margin` — solve-time
      injections for a block attempt;
    * :meth:`after_block_saved` — storage abuse (corrupt/truncate the
      just-written chunk) and the mid-build kill.

    ``armed=False`` turns every hook into a no-op (the clean control arm
    of the benchmark, and the state after a campaign decides it has
    injected enough).
    """

    def __init__(self, plan: FaultPlan, armed: bool = True):
        self.plan = plan
        self.armed = armed
        self.saves_this_run = 0
        self.kill_armed = plan.kill_after_blocks is not None
        # ledger of everything injected, for test/benchmark accounting
        self.log: list[dict] = []

    # -- solve-time seams -------------------------------------------------
    def block_fault(self, block_id: int, attempt: int) -> MatvecFault | None:
        """Matvec corruption for (block, attempt), or None. Applies to
        attempts < plan.transient_attempts, so the default is a
        TRANSIENT fault: the guards flag it, the driver's same-rung
        retry recomputes the block clean."""
        p = self.plan
        if not self.armed or attempt >= p.transient_attempts or \
                _hash01(p.seed, int(block_id), "nan") >= \
                p.matvec_nan_fraction:
            return None
        lanes = tuple(range(p.matvec_nan_pairs))
        self.log.append({"kind": "matvec_nan", "block": int(block_id),
                         "attempt": attempt, "pairs": list(lanes)})
        return MatvecFault(pairs=lanes, start=p.fault_start,
                           stop=p.fault_stop, value=p.matvec_nan_value)

    def block_spd_margin(self, block_id: int, attempt: int,
                         precond: str) -> float | None:
        """Forced-negative SPD margin for (block, attempt) — only
        meaningful when the attempt actually solves with the kron
        preconditioner (a jacobi rung has no certificate to fail)."""
        p = self.plan
        if not self.armed or precond != "kron" or \
                attempt >= p.transient_attempts or \
                _hash01(p.seed, int(block_id), "cert") >= \
                p.cert_fail_fraction:
            return None
        self.log.append({"kind": "cert_fail", "block": int(block_id),
                         "attempt": attempt, "margin": p.cert_margin})
        return p.cert_margin

    # -- storage / liveness seams ----------------------------------------
    def after_block_saved(self, store, block_id: int) -> None:
        """Called by the driver right after a successful save_block.
        Abuses the chunk bytes on disk per the plan, then possibly
        kills the driver. Corruption happens BEFORE the kill check so a
        killed run leaves corrupt chunks behind for the restart to
        discover — the nastiest ordering."""
        if not self.armed:
            return
        p = self.plan
        path = store.block_path(block_id)
        if _hash01(p.seed, int(block_id), "corrupt") < p.corrupt_fraction:
            self._flip_byte(path)
            self.log.append({"kind": "corrupt", "block": int(block_id)})
        elif _hash01(p.seed, int(block_id), "trunc") < \
                p.truncate_fraction:
            self._truncate(path)
            self.log.append({"kind": "truncate", "block": int(block_id)})
        self.saves_this_run += 1
        if self.kill_armed and p.kill_after_blocks is not None and \
                self.saves_this_run >= p.kill_after_blocks:
            self.kill_armed = False
            self.log.append({"kind": "kill", "after_block": int(block_id)})
            raise DriverKilled(
                f"injected driver kill after {self.saves_this_run} "
                f"blocks (block {block_id} saved)")

    @staticmethod
    def _flip_byte(path: str) -> None:
        try:
            with open(path, "r+b") as f:
                f.seek(0, os.SEEK_END)
                size = f.tell()
                if size == 0:
                    return
                f.seek(size // 2)
                b = f.read(1)
                f.seek(size // 2)
                f.write(bytes([b[0] ^ 0xFF]) if b else b"\xff")
        except OSError:
            pass

    @staticmethod
    def _truncate(path: str) -> None:
        try:
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(size // 2)
        except OSError:
            pass

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for rec in self.log:
            out[rec["kind"]] = out.get(rec["kind"], 0) + 1
        return out


def run_campaign(driver_factory, plan: FaultPlan, *,
                 max_restarts: int = 20):
    """Drive a Gram build to completion through a fault campaign.

    ``driver_factory(injector)`` must return a FRESH
    :class:`~repro.distributed.gram.GramDriver` wired to the SAME
    ChunkStore each time (a restarted driver process). The loop runs the
    driver, catches each injected :class:`DriverKilled`, and restarts —
    exactly the operational story: crash, restart against the store,
    recompute only what's missing.

    Returns ``(K, report)`` — the assembled Gram matrix and a dict with
    the injection ledger, restart count, and the final driver's health
    record (retries/escalations/quarantines), which tests and
    ``benchmarks/faults_bench.py`` reconcile against a fault-free run.
    """
    injector = FaultInjector(plan)
    restarts = 0
    while True:
        injector.saves_this_run = 0
        driver = driver_factory(injector)
        try:
            K = driver.run()
            break
        except DriverKilled:
            restarts += 1
            if restarts > max_restarts:
                raise
    report = {
        "restarts": restarts,
        "injections": injector.counts(),
        "injection_log": list(injector.log),
        "health": dict(getattr(driver, "health", {}) or {}),
    }
    return K, report
