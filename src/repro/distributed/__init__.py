"""Distributed Gram-matrix runtime: cost-model scheduling, chunked
checkpoint/restart, elastic re-planning, straggler speculation, the
sharded pair-solve step (paper Sec. V scaled from one GPU to a pod
mesh), and the self-healing layer — degradation ladder, journaled
manifest, deterministic fault injection (DESIGN.md §10)."""
from .scheduler import SchedulePlan, make_plan, replan
from .checkpoint import ChunkStore, assemble_blocks, \
    save_array_checkpoint, load_array_checkpoint
from .gram import GramDriver, gram_pair_step, solve_pair_block
from .faults import DriverKilled, FaultInjector, FaultPlan, run_campaign

__all__ = [
    "SchedulePlan", "make_plan", "replan", "ChunkStore",
    "assemble_blocks", "save_array_checkpoint", "load_array_checkpoint",
    "GramDriver", "gram_pair_step", "solve_pair_block",
    "DriverKilled", "FaultInjector", "FaultPlan", "run_campaign",
]
