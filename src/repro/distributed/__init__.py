"""Distributed Gram-matrix runtime: cost-model scheduling, chunked
checkpoint/restart, elastic re-planning, straggler speculation, and the
sharded pair-solve step (paper Sec. V scaled from one GPU to a pod mesh)."""
from .scheduler import SchedulePlan, make_plan, replan
from .checkpoint import ChunkStore, save_array_checkpoint, \
    load_array_checkpoint
from .gram import GramDriver, gram_pair_step, solve_pair_block

__all__ = [
    "SchedulePlan", "make_plan", "replan", "ChunkStore",
    "save_array_checkpoint", "load_array_checkpoint", "GramDriver",
    "gram_pair_step", "solve_pair_block",
]
