"""Checkpoint/restart for long-running distributed jobs.

Two granularities:

* :class:`ChunkStore` — the Gram pipeline's unit of fault tolerance. Every
  completed PairBlock's results land as one CRC-protected, atomically
  renamed file plus a manifest entry. Restart = scan manifest, recompute
  only missing blocks. First-writer-wins semantics make straggler
  speculation safe: a duplicate completion of the same block is a no-op.
* :func:`save_array_checkpoint` / :func:`load_array_checkpoint` — pytree
  checkpoints for LM training state (params/optimizer/step), also
  CRC + atomic-rename, with a rolling ``keep_last`` window.

No external deps: npz + json. On a real fleet the directory would live on
a parallel filesystem / object store; the protocol (atomic rename +
manifest scan) is the portable part.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any

import numpy as np

import jax

__all__ = ["ChunkStore", "assemble_blocks", "save_array_checkpoint",
           "load_array_checkpoint"]


def _atomic_write(path: str, data: bytes) -> None:
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)


class ChunkStore:
    """Directory-backed store of per-block results with a manifest."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._manifest_path = os.path.join(root, "manifest.json")

    # -- manifest ---------------------------------------------------------
    def done_blocks(self) -> set[int]:
        if not os.path.exists(self._manifest_path):
            return set()
        with open(self._manifest_path) as f:
            manifest = json.load(f)
        return {int(k) for k, v in manifest.items() if v.get("crc") is not None}

    def _update_manifest(self, block_id: int, entry: dict) -> None:
        manifest = {}
        if os.path.exists(self._manifest_path):
            with open(self._manifest_path) as f:
                manifest = json.load(f)
        if str(block_id) in manifest:
            return  # first writer wins (straggler duplicate)
        manifest[str(block_id)] = entry
        _atomic_write(self._manifest_path,
                      json.dumps(manifest, indent=0).encode())

    # -- results ----------------------------------------------------------
    def block_path(self, block_id: int) -> str:
        return os.path.join(self.root, f"block_{block_id:08d}.npz")

    def save_block(self, block_id: int, rows: np.ndarray, cols: np.ndarray,
                   values: np.ndarray, iterations: np.ndarray,
                   **extra: np.ndarray) -> bool:
        """Returns False if the block was already recorded (speculation).

        ``extra`` arrays (e.g. the gradient Gram blocks ``grad_<theta>``
        of GramDriver.run_with_grad) ride in the same npz under their
        given names and come back verbatim from :meth:`load_block`."""
        if block_id in self.done_blocks():
            return False
        import io
        buf = io.BytesIO()
        np.savez(buf, rows=rows, cols=cols, values=values,
                 iterations=iterations, **extra)
        data = buf.getvalue()
        path = self.block_path(block_id)
        _atomic_write(path, data)
        self._update_manifest(block_id, {
            "crc": zlib.crc32(data), "n_pairs": int(len(rows)),
        })
        return True

    def load_block(self, block_id: int) -> dict[str, np.ndarray]:
        path = self.block_path(block_id)
        with open(path, "rb") as f:
            data = f.read()
        with open(self._manifest_path) as f:
            manifest = json.load(f)
        want = manifest[str(block_id)]["crc"]
        got = zlib.crc32(data)
        if want != got:
            raise IOError(
                f"block {block_id} CRC mismatch ({got} != {want}) — corrupt "
                "checkpoint; delete the file to force recompute")
        import io
        return dict(np.load(io.BytesIO(data)))

    def assemble_gram(self, n: int, normalize: bool = False,
                      key: str = "values") -> np.ndarray:
        """Gather all completed blocks into the (symmetric) Gram matrix
        (``key`` selects which per-block array — e.g. a ``grad_<theta>``
        gradient block)."""
        K = assemble_blocks(
            (self.load_block(bid) for bid in sorted(self.done_blocks())),
            n, key)
        if normalize:
            d = np.sqrt(np.diag(K))
            K = K / d[:, None] / d[None, :]
        return K


def assemble_blocks(blocks, n: int, key: str = "values") -> np.ndarray:
    """THE fill-and-mirror Gram assembly convention (NaN init for
    missing entries, symmetric scatter by each block's own rows/cols) —
    single implementation shared by :meth:`ChunkStore.assemble_gram` and
    the driver's in-memory path (distributed/gram.py)."""
    M = np.full((n, n), np.nan, np.float64)
    for blk in blocks:
        M[blk["rows"], blk["cols"]] = blk[key]
        M[blk["cols"], blk["rows"]] = blk[key]
    return M


# -- pytree checkpoints for LM training --------------------------------------

def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_array_checkpoint(root: str, step: int, tree: Any,
                          keep_last: int = 3) -> str:
    os.makedirs(root, exist_ok=True)
    flat, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(flat)}
    import io
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    data = buf.getvalue()
    path = os.path.join(root, f"ckpt_{step:010d}.npz")
    _atomic_write(path, data)
    meta = {"step": step, "crc": zlib.crc32(data), "n_arrays": len(flat)}
    _atomic_write(path + ".json", json.dumps(meta).encode())
    # rolling window
    ckpts = sorted(p for p in os.listdir(root)
                   if p.startswith("ckpt_") and p.endswith(".npz"))
    for old in ckpts[:-keep_last]:
        os.remove(os.path.join(root, old))
        meta_p = os.path.join(root, old + ".json")
        if os.path.exists(meta_p):
            os.remove(meta_p)
    return path


def load_array_checkpoint(root: str, tree_like: Any,
                          step: int | None = None) -> tuple[Any, int]:
    """Restore the latest (or given-step) checkpoint into tree_like's
    structure. Verifies CRC; skips corrupt checkpoints and falls back to
    the previous one (fault tolerance on restore)."""
    ckpts = sorted(p for p in os.listdir(root)
                   if p.startswith("ckpt_") and p.endswith(".npz"))
    if step is not None:
        ckpts = [p for p in ckpts if p == f"ckpt_{step:010d}.npz"]
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints under {root}")
    for name in reversed(ckpts):
        path = os.path.join(root, name)
        try:
            with open(path, "rb") as f:
                data = f.read()
            with open(path + ".json") as f:
                meta = json.load(f)
            if zlib.crc32(data) != meta["crc"]:
                continue  # corrupt; try the previous one
            import io
            loaded = np.load(io.BytesIO(data))
            flat, treedef = jax.tree_util.tree_flatten(tree_like)
            restored = [loaded[f"a{i}"] for i in range(len(flat))]
            return jax.tree_util.tree_unflatten(treedef, restored), \
                meta["step"]
        except (IOError, KeyError):
            continue
    raise IOError(f"all checkpoints under {root} are corrupt")
