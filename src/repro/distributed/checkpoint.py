"""Checkpoint/restart for long-running distributed jobs.

Two granularities:

* :class:`ChunkStore` — the Gram pipeline's unit of fault tolerance. Every
  completed PairBlock's results land as one CRC-protected, atomically
  renamed file plus a manifest record. Restart = replay manifest, recompute
  only missing blocks. First-writer-wins semantics make straggler
  speculation safe: a duplicate completion of the same block is a no-op.
* :func:`save_array_checkpoint` / :func:`load_array_checkpoint` — pytree
  checkpoints for LM training state (params/optimizer/step), also
  CRC + atomic-rename, with a rolling ``keep_last`` window.

Manifest = append-only journal (DESIGN.md §10.3). The original
read-modify-rewrite of one ``manifest.json`` per completed block was
O(blocks²) in total I/O and, worse, NOT crash-safe: a kill between read
and atomic rewrite could persist a manifest missing entries whose block
files exist. The store now appends one fsync'd JSON line per event to
``manifest.jsonl``:

    {"op": "add",        "block": 17, "crc": ..., "n_pairs": ...}
    {"op": "quarantine", "block": 17, "reason": "crc mismatch ..."}
    {"op": "note",       ...}            # driver health/summary records

Replay folds the journal in order: the FIRST ``add`` for a block wins
(straggler speculation) — unless a later ``quarantine`` retired it, after
which a subsequent ``add`` (the recompute) takes effect again. A torn
final line (crash mid-append) is tolerated and dropped on replay; the
journal is compacted (atomic rewrite of the folded state) when garbage
exceeds a threshold. A legacy ``manifest.json`` found without a journal
is migrated on first open.

No external deps: npz + json. On a real fleet the directory would live on
a parallel filesystem / object store; the protocol (atomic rename +
append-only journal) is the portable part.
"""
from __future__ import annotations

import json
import os
import warnings
import zlib
from typing import Any, Iterable

import numpy as np

import jax

__all__ = ["ChunkStore", "assemble_blocks", "save_array_checkpoint",
           "load_array_checkpoint"]


def _atomic_write(path: str, data: bytes) -> None:
    """Write-fsync-rename. The tmp suffix is pid PLUS random bytes —
    pid alone collides across hosts on shared storage — and the tmp file
    is unlinked on ANY failure between write and rename (the old code
    stranded it forever; :class:`ChunkStore` additionally reaps strays
    left by a hard kill, which no in-process cleanup can catch)."""
    tmp = path + f".tmp.{os.getpid()}.{os.urandom(4).hex()}"
    try:
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class ChunkStore:
    """Directory-backed store of per-block results with a journaled
    manifest (module docstring; DESIGN.md §10.3).

    The store assumes a SINGLE live writer per directory (the Gram
    driver; a crashed predecessor is by definition dead), which is what
    makes reaping every ``*.tmp.*`` stray at ``__init__`` safe.
    """

    def __init__(self, root: str, reap_tmps: bool = True,
                 compact_threshold: float = 4.0):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._journal_path = os.path.join(root, "manifest.jsonl")
        self._legacy_path = os.path.join(root, "manifest.json")
        self._compact_threshold = compact_threshold
        self._cache = None          # (size, folded-state)
        if reap_tmps:
            self.reap_stale_tmps()
        self._migrate_legacy()
        # compact eagerly at open: restart is the one moment no writer
        # is mid-append and the journal is about to be replayed anyway
        st = self._state()
        live = len(st["blocks"]) + len(st["quarantined"]) + len(
            st["notes"])
        if st["n_lines"] > 64 and st["n_lines"] > compact_threshold * \
                max(live, 1):
            self.compact_manifest()

    # -- journal ----------------------------------------------------------
    def reap_stale_tmps(self) -> list[str]:
        """Delete stranded ``*.tmp.*`` files (crash between write and
        rename). Returns the reaped names."""
        reaped = []
        for name in os.listdir(self.root):
            if ".tmp." in name:
                try:
                    os.unlink(os.path.join(self.root, name))
                    reaped.append(name)
                except OSError:
                    pass
        return reaped

    def _migrate_legacy(self) -> None:
        if os.path.exists(self._journal_path) or \
                not os.path.exists(self._legacy_path):
            return
        with open(self._legacy_path) as f:
            legacy = json.load(f)
        lines = [json.dumps({"op": "add", "block": int(k), **v})
                 for k, v in sorted(legacy.items(),
                                    key=lambda kv: int(kv[0]))]
        _atomic_write(self._journal_path,
                      ("\n".join(lines) + "\n").encode()
                      if lines else b"")

    def _fold(self, data: bytes) -> dict:
        """Replay journal bytes into folded state. A torn tail line
        (crash mid-append) parses as garbage and is dropped; any OTHER
        unparseable line is counted (real corruption — the journal is
        append-only, so only the tail can legitimately be torn)."""
        blocks: dict[int, dict] = {}
        quarantined: dict[int, dict] = {}
        notes: list[dict] = []
        raw = data.split(b"\n")
        n_lines = 0
        torn = 0
        for i, line in enumerate(raw):
            if not line.strip():
                continue
            n_lines += 1
            try:
                rec = json.loads(line)
            except ValueError:
                torn += 1
                if i < len(raw) - 2:        # not the (possibly torn) tail
                    warnings.warn(
                        f"manifest journal line {i} unparseable "
                        "(mid-file corruption); skipped")
                continue
            op = rec.get("op", "add")
            if op == "add":
                bid = int(rec["block"])
                if bid not in blocks:       # first writer wins
                    blocks[bid] = {k: v for k, v in rec.items()
                                   if k not in ("op", "block")}
                    quarantined.pop(bid, None)   # recompute cleared it
            elif op == "quarantine":
                bid = int(rec["block"])
                blocks.pop(bid, None)
                quarantined[bid] = {k: v for k, v in rec.items()
                                    if k not in ("op", "block")}
            elif op == "note":
                notes.append({k: v for k, v in rec.items() if k != "op"})
        return {"blocks": blocks, "quarantined": quarantined,
                "notes": notes, "n_lines": n_lines, "n_torn": torn}

    def _state(self) -> dict:
        """Folded journal state, cached by file size (append-only ⇒ any
        concurrent append grows the file, so size is a valid version)."""
        try:
            size = os.path.getsize(self._journal_path)
        except OSError:
            size = -1
        if self._cache is not None and self._cache[0] == size:
            return self._cache[1]
        data = b""
        if size >= 0:
            with open(self._journal_path, "rb") as f:
                data = f.read()
        st = self._fold(data)
        self._cache = (size, st)
        return st

    def _append(self, record: dict) -> None:
        line = (json.dumps(record) + "\n").encode()
        with open(self._journal_path, "ab") as f:
            f.write(line)
            f.flush()
            os.fsync(f.fileno())
        self._cache = None

    def compact_manifest(self) -> int:
        """Atomically rewrite the journal as its folded state (one line
        per live record). Returns the number of lines dropped."""
        st = self._state()
        lines = [json.dumps({"op": "add", "block": bid, **entry})
                 for bid, entry in sorted(st["blocks"].items())]
        lines += [json.dumps({"op": "quarantine", "block": bid, **entry})
                  for bid, entry in sorted(st["quarantined"].items())]
        lines += [json.dumps({"op": "note", **n}) for n in st["notes"]]
        _atomic_write(self._journal_path,
                      ("\n".join(lines) + "\n").encode()
                      if lines else b"")
        self._cache = None
        return st["n_lines"] - len(lines)

    # -- manifest queries -------------------------------------------------
    def done_blocks(self) -> set[int]:
        return set(self._state()["blocks"])

    def block_entry(self, block_id: int) -> dict | None:
        """The manifest record of one completed block (crc, n_pairs,
        plus any driver ``meta`` — health counters, escalation rung)."""
        return self._state()["blocks"].get(int(block_id))

    def quarantined_blocks(self) -> dict[int, dict]:
        """Blocks quarantined (CRC mismatch / torn file) and not yet
        successfully recomputed — never silently part of the Gram."""
        return dict(self._state()["quarantined"])

    def notes(self) -> list[dict]:
        """Free-form journal records (driver health summaries)."""
        return list(self._state()["notes"])

    def note(self, **fields) -> None:
        """Append a free-form record to the journal (driver summaries:
        per-bucket non-convergence counts, quarantined pairs, ladder
        escalations — the 'accounted for in the manifest' channel)."""
        self._append({"op": "note", **fields})

    # -- results ----------------------------------------------------------
    def block_path(self, block_id: int) -> str:
        return os.path.join(self.root, f"block_{block_id:08d}.npz")

    def save_block(self, block_id: int, rows: np.ndarray, cols: np.ndarray,
                   values: np.ndarray, iterations: np.ndarray,
                   meta: dict | None = None,
                   **extra: np.ndarray) -> bool:
        """Returns False if the block was already recorded (speculation).

        ``extra`` arrays (e.g. the gradient Gram blocks ``grad_<theta>``
        of GramDriver.run_with_grad) ride in the same npz under their
        given names and come back verbatim from :meth:`load_block`;
        ``meta`` (JSON-serializable) rides in the manifest record
        (:meth:`block_entry`) — the driver's per-block health channel."""
        if block_id in self.done_blocks():
            return False
        import io
        buf = io.BytesIO()
        np.savez(buf, rows=rows, cols=cols, values=values,
                 iterations=iterations, **extra)
        data = buf.getvalue()
        path = self.block_path(block_id)
        _atomic_write(path, data)
        self._append({"op": "add", "block": int(block_id),
                      "crc": zlib.crc32(data), "n_pairs": int(len(rows)),
                      **(meta or {})})
        return True

    def quarantine_block(self, block_id: int, reason: str) -> None:
        """Retire a block from the done set (journal tombstone) and move
        its file aside for forensics. A later :meth:`save_block` of the
        same id (the recompute) takes effect despite first-writer-wins."""
        path = self.block_path(block_id)
        if os.path.exists(path):
            try:
                os.replace(path, path + ".quarantined")
            except OSError:
                pass
        self._append({"op": "quarantine", "block": int(block_id),
                      "reason": reason})

    def load_block(self, block_id: int,
                   on_error: str = "raise") -> dict[str, np.ndarray] | None:
        """Load one block, verifying its CRC against the manifest.

        The CRC is computed over the WHOLE file, so truncation (a torn
        chunk restored from a crashed copy) is caught identically to bit
        corruption, before np.load ever parses the bytes.

        on_error: "raise" (default) raises IOError on a corrupt/missing/
        truncated chunk; "quarantine" instead journals a tombstone,
        moves the bad file aside, and returns None — the restart path's
        recompute-instead-of-abort mode (DESIGN.md §10.3)."""
        if on_error not in ("raise", "quarantine"):
            raise ValueError(f"unknown on_error={on_error!r}")
        path = self.block_path(block_id)
        entry = self.block_entry(block_id)
        err = None
        data = None
        if entry is None:
            err = f"block {block_id} not in manifest"
        else:
            try:
                with open(path, "rb") as f:
                    data = f.read()
            except OSError as e:
                err = f"block {block_id} unreadable: {e}"
        if err is None:
            want, got = entry["crc"], zlib.crc32(data)
            if want != got:
                kind = "truncated" if len(data) == 0 else "corrupt"
                err = (f"block {block_id} CRC mismatch ({got} != {want})"
                       f" — {kind} chunk")
        if err is not None:
            if on_error == "quarantine":
                self.quarantine_block(block_id, err)
                return None
            raise IOError(err + "; delete the file (or load with "
                          "on_error='quarantine') to force recompute")
        import io
        return dict(np.load(io.BytesIO(data)))

    def assemble_gram(self, n: int, normalize: bool = False,
                      key: str = "values", strict: bool = True,
                      expected_blocks: Iterable[int] | None = None
                      ) -> np.ndarray:
        """Gather all completed blocks into the (symmetric) Gram matrix
        (``key`` selects which per-block array — e.g. a ``grad_<theta>``
        gradient block). With ``expected_blocks``, missing ids are
        reported by id; either way ``strict=True`` (default) refuses to
        return a Gram with silent NaN holes (:func:`assemble_blocks`)."""
        done = sorted(self.done_blocks())
        if expected_blocks is not None:
            missing = sorted(set(int(b) for b in expected_blocks)
                             - set(done))
            if missing:
                msg = (f"{len(missing)} block(s) missing from store: "
                       f"{missing[:20]}"
                       + ("..." if len(missing) > 20 else ""))
                if strict:
                    raise ValueError(msg)
                warnings.warn(msg)
        K = assemble_blocks((self.load_block(bid) for bid in done), n,
                            key, strict=strict)
        if normalize:
            d = np.sqrt(np.diag(K))
            K = K / d[:, None] / d[None, :]
        return K


def assemble_blocks(blocks, n: int, key: str = "values",
                    strict: bool = True) -> np.ndarray:
    """THE fill-and-mirror Gram assembly convention (NaN init for
    missing entries, symmetric scatter by each block's own rows/cols) —
    single implementation shared by :meth:`ChunkStore.assemble_gram` and
    the driver's in-memory path (distributed/gram.py).

    A NaN hole in the result means a missing block or an excluded
    (quarantined) pair — either way, silently returning it poisons any
    downstream training run. ``strict=True`` (default) raises instead,
    reporting the uncovered index pairs; ``strict=False`` warns and
    returns the holed matrix (callers that want the hole MASK can take
    ``np.isnan`` of it — the quarantine-aware driver path does)."""
    M = np.full((n, n), np.nan, np.float64)
    for blk in blocks:
        if blk is None:
            continue          # a quarantined block (load_block -> None)
        M[blk["rows"], blk["cols"]] = blk[key]
        M[blk["cols"], blk["rows"]] = blk[key]
    holes = np.argwhere(np.isnan(M))
    if holes.size:
        ij = [tuple(int(v) for v in h) for h in holes[:10]]
        msg = (f"Gram assembly left {len(holes)} NaN hole(s) "
               f"(missing blocks or quarantined pairs), e.g. {ij}")
        if strict:
            raise ValueError(
                msg + "; pass strict=False to get the holed matrix")
        warnings.warn(msg)
    return M


# -- pytree checkpoints for LM training --------------------------------------

def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_array_checkpoint(root: str, step: int, tree: Any,
                          keep_last: int = 3) -> str:
    os.makedirs(root, exist_ok=True)
    flat, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(flat)}
    import io
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    data = buf.getvalue()
    path = os.path.join(root, f"ckpt_{step:010d}.npz")
    _atomic_write(path, data)
    meta = {"step": step, "crc": zlib.crc32(data), "n_arrays": len(flat)}
    _atomic_write(path + ".json", json.dumps(meta).encode())
    # rolling window
    ckpts = sorted(p for p in os.listdir(root)
                   if p.startswith("ckpt_") and p.endswith(".npz"))
    for old in ckpts[:-keep_last]:
        os.remove(os.path.join(root, old))
        meta_p = os.path.join(root, old + ".json")
        if os.path.exists(meta_p):
            os.remove(meta_p)
    return path


def load_array_checkpoint(root: str, tree_like: Any,
                          step: int | None = None) -> tuple[Any, int]:
    """Restore the latest (or given-step) checkpoint into tree_like's
    structure. Verifies CRC; skips corrupt checkpoints and falls back to
    the previous one (fault tolerance on restore)."""
    ckpts = sorted(p for p in os.listdir(root)
                   if p.startswith("ckpt_") and p.endswith(".npz"))
    if step is not None:
        ckpts = [p for p in ckpts if p == f"ckpt_{step:010d}.npz"]
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints under {root}")
    for name in reversed(ckpts):
        path = os.path.join(root, name)
        try:
            with open(path, "rb") as f:
                data = f.read()
            with open(path + ".json") as f:
                meta = json.load(f)
            if zlib.crc32(data) != meta["crc"]:
                continue  # corrupt; try the previous one
            import io
            loaded = np.load(io.BytesIO(data))
            flat, treedef = jax.tree_util.tree_flatten(tree_like)
            restored = [loaded[f"a{i}"] for i in range(len(flat))]
            return jax.tree_util.tree_unflatten(treedef, restored), \
                meta["step"]
        except (IOError, KeyError):
            continue
    raise IOError(f"all checkpoints under {root} are corrupt")
