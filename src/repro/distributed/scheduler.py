"""Cost-model scheduling of all-pairs work (paper Sec. V-B, at fleet scale).

The paper observes that load imbalance comes from "variation of graph size
and sparsity pattern that affect the problem size as well as the number of
CG iterations". At a thousand nodes this is the dominant effect (DrugBank
sizes span 1..551 => per-pair cost varies by ~9e10). Design:

* every PairBlock carries a cost estimate (pairs x (n*m)^2 x predicted
  iterations — sparse blocks scaled by octile density);
* blocks are placed with Longest-Processing-Time greedy onto device groups
  (optimal within 4/3 of makespan);
* the placement is a pure function of (blocks, n_groups) — growing or
  shrinking the fleet between chunks just calls :func:`replan` on the
  remaining blocks (elasticity);
* the last ``speculate_tail`` fraction of each group's queue is mirrored
  onto the least-loaded other group (straggler mitigation; the ChunkStore's
  first-writer-wins manifest deduplicates results).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.loader import PairBlock

__all__ = ["SchedulePlan", "make_plan", "replan", "estimate_cost",
           "DEFAULT_ITERS"]

# prior iteration counts per preconditioner type, used for blocks no
# measurement exists for yet: the Kronecker-factored approximate
# inverse (core/precond.py, DESIGN.md §9) reaches tolerance in ≥30%
# fewer PCG iterations than Jacobi on the BENCH_pcg fixtures, so a
# kron-preconditioned fleet's cost model must not assume Jacobi trip
# counts — it would systematically over-reserve capacity per block and
# skew the LPT placement toward stale load estimates.
DEFAULT_ITERS = {"jacobi": 32.0, "kron": 20.0}


def estimate_cost(block: PairBlock, density: float = 1.0,
                  iters: float | None = None,
                  precond: str = "jacobi") -> float:
    """Predicted work of a block: Sum_pairs (n*m)^2 * density^2 * iters.

    density is the mean octile occupancy after reordering (1.0 = dense);
    the XMV touches density^2 of the tile products. Both knobs are fed
    by measurements when available: the Gram driver's `GraphPackCache`
    records each graph's real octile occupancy at pack time, and
    finished blocks report their per-pair CG iteration counts
    (``PCGResult.iterations``) — see ``GramDriver.plan``. Blocks no
    measurement exists for yet fall back to the ``DEFAULT_ITERS`` prior
    KEYED ON THE PRECONDITIONER TYPE (``iters=None``).
    """
    if iters is None:
        iters = DEFAULT_ITERS.get(precond, DEFAULT_ITERS["jacobi"])
    return block.cost() * (density ** 2) * iters


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """assignment[g] = ordered list of block ids for device-group g;
    speculative[g] = block ids mirrored onto g as straggler backups."""
    n_groups: int
    assignment: tuple[tuple[int, ...], ...]
    speculative: tuple[tuple[int, ...], ...]
    loads: tuple[float, ...]

    @property
    def makespan_ratio(self) -> float:
        """max load / mean load — 1.0 is perfect balance."""
        loads = np.asarray(self.loads)
        mean = loads.mean() if loads.size else 0.0
        return float(loads.max() / mean) if mean > 0 else 1.0


def make_plan(blocks: list[PairBlock], n_groups: int,
              densities: dict[int, float] | None = None,
              speculate_tail: float = 0.05,
              iters: dict[int, float] | None = None,
              precond: str = "jacobi",
              failures: dict[int, int] | None = None) -> SchedulePlan:
    """LPT greedy placement of blocks onto n_groups device groups.

    ``densities``/``iters`` map block ids to measured per-block octile
    occupancy and predicted CG iteration counts (blocks absent from the
    dicts use the :func:`estimate_cost` defaults — the iteration prior
    keyed on ``precond``).

    ``failures`` maps block ids to observed solve-failure counts (the
    Gram driver's degradation-ladder feedback, DESIGN.md §10.2): a
    failing block likely retries or escalates to slower rungs, so (a) it
    is DEPRIORITIZED — demoted to the tail of its group's queue, ordered
    by failure count, so healthy work lands first and a poison bucket
    can't starve the fleet — and (b) it is EXCLUDED from straggler
    speculation (mirroring a block that fails deterministically just
    fails twice)."""
    densities = densities or {}
    iters = iters or {}
    failures = failures or {}
    costs = np.array([estimate_cost(b, densities.get(b.block_id, 1.0),
                                    iters.get(b.block_id),
                                    precond=precond)
                      for b in blocks])
    order = np.argsort(-costs)  # heaviest first
    loads = np.zeros(n_groups)
    queues: list[list[int]] = [[] for _ in range(n_groups)]
    for k in order:
        g = int(np.argmin(loads))
        queues[g].append(blocks[int(k)].block_id)
        loads[g] += costs[k]
    # demote failing blocks to the queue tail (stable within each class)
    if failures:
        queues = [
            [bid for bid in q if not failures.get(bid)]
            + sorted((bid for bid in q if failures.get(bid)),
                     key=lambda bid: failures[bid])
            for q in queues]
    # straggler speculation: mirror each group's tail onto the least-loaded
    # *other* group
    spec: list[list[int]] = [[] for _ in range(n_groups)]
    if n_groups > 1 and speculate_tail > 0:
        for g, q in enumerate(queues):
            n_tail = max(1, int(len(q) * speculate_tail)) if q else 0
            for bid in q[-n_tail:]:
                if failures.get(bid):
                    continue    # don't mirror deterministic failures
                others = [(loads[h], h) for h in range(n_groups) if h != g]
                _, h = min(others)
                spec[h].append(bid)
    return SchedulePlan(
        n_groups=n_groups,
        assignment=tuple(tuple(q) for q in queues),
        speculative=tuple(tuple(s) for s in spec),
        loads=tuple(float(x) for x in loads),
    )


def replan(blocks: list[PairBlock], done_ids: set[int], n_groups: int,
           densities: dict[int, float] | None = None,
           iters: dict[int, float] | None = None,
           precond: str = "jacobi",
           failures: dict[int, int] | None = None) -> SchedulePlan:
    """Elastic re-planning: schedule only the not-yet-done blocks for the
    *current* group count. Deterministic given (blocks, done, n_groups,
    failures)."""
    remaining = [b for b in blocks if b.block_id not in done_ids]
    return make_plan(remaining, n_groups, densities, iters=iters,
                     precond=precond, failures=failures)
