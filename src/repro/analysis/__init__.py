"""Static analysis of compiled HLO: loop-trip-corrected flops / bytes /
collective traffic (the dry-run profile that feeds §Roofline)."""
from .hlo_cost import analyze_hlo, HloCost

__all__ = ["analyze_hlo", "HloCost"]
