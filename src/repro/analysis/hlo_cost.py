"""Loop-trip-corrected static cost model over compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` counts every while-loop body
ONCE — for scan-over-layers models that under-reports flops/bytes by a
factor of n_layers, and the same bias hits any collective op inside the
scanned body. XLA does annotate each while with
``backend_config={"known_trip_count":{"n":"N"}}``, so an exact correction
is computable from the HLO text alone:

  cost(entry) = sum over instructions, where
    while ops contribute trip_count * (cost(body) + cost(cond)),
    fusion/call ops contribute cost(called computation),
    dots contribute 2 * prod(result_dims) * prod(contracting_dims),
    elementwise/reduce ops contribute ~1 flop/element,
    HBM bytes are counted at fusion boundaries (operands + result),
    collective link-bytes use a ring model per replica group.

This is a *static* profile — exactly what the tasking's "your profile is
lowered.as_text() + cost_analysis()" loop needs, with the loop bias fixed.
Validated against analytic 6*N*D in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# 1 flop per element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "compare", "select", "and", "or", "xor", "not", "negate", "abs",
    "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "atan2",
}
# transcendental: count a few flops per element
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "tan", "erf", "expm1",
                   "log1p", "cbrt", "exponential-minus-one"}
_ZERO_FLOP = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "reshape", "transpose", "broadcast", "iota",
              "copy", "convert", "slice", "dynamic-slice",
              "dynamic-update-slice", "concatenate", "pad", "reverse",
              "gather", "scatter", "reduce", "reduce-window", "rng",
              "after-all", "custom-call", "bitcast-convert", "copy-start",
              "copy-done", "optimization-barrier", "partition-id",
              "replica-id", "domain", "infeed", "outfeed"}
# bytes are NOT counted for these (pure aliasing / metadata)
_NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "optimization-barrier", "domain",
             "partition-id", "replica-id"}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\((.*)$")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP = re.compile(r'known_trip_count[\\\":{\s]+n[\\\":\s]+(\d+)')
_GROUPS1 = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS = re.compile(r"(?:calls|body|condition|to_apply)=(%[\w\.\-]+)")
_OPERAND = re.compile(r"%[\w\.\-]+")


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    """(elements, bytes) summed over all dtype[dims] tokens in text."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    shape_txt: str
    opcode: str
    rest: str          # everything after the opening paren
    elems: int
    bytes_: int

    def operand_names(self) -> list[str]:
        # operands live before the closing paren of the op
        depth = 1
        out = []
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    out_str = self.rest[:i]
                    return _OPERAND.findall(out_str)
        return _OPERAND.findall(self.rest)


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collectives: dict
    n_while: int
    unknown_trip_loops: int

    @property
    def total_link_bytes(self) -> float:
        return sum(v["link_bytes"] for v in self.collectives.values())


def _parse(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = []
            comps[m.group(1)] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR.match(line)
        if im:
            name, shape_txt, opcode, rest = im.groups()
            elems, byts = _shape_elems_bytes(shape_txt)
            cur.append(Instr(name, shape_txt, opcode, rest, elems, byts))
    return comps


def analyze_hlo(text: str) -> HloCost:
    comps = _parse(text)
    # entry = the computation that no other computation references
    referenced: set[str] = set()
    for instrs in comps.values():
        for ins in instrs:
            for c in _CALLS.findall(ins.rest):
                referenced.add(c)
    entries = [c for c in comps if c not in referenced]
    # prefer the largest unreferenced computation
    entry = max(entries, key=lambda c: len(comps[c])) if entries else \
        max(comps, key=lambda c: len(comps[c]))

    memo: dict[str, tuple] = {}
    stats = {"n_while": 0, "unknown": 0}

    _SLICERS = {"dynamic-slice", "slice", "gather", "bitcast",
                "get-tuple-element", "reshape", "transpose", "copy",
                "convert"}

    def _param_instr(comp: str, idx: int):
        for ins in comps.get(comp, []):
            if ins.opcode == "parameter" and ins.rest.startswith(f"{idx})"):
                return ins
        return None

    def _fusion_bytes(called: str, ins: Instr) -> float:
        """HBM traffic of one fusion call (the unit XLA schedules):

        * output: result bytes, except dynamic-update-slice results which
          alias their buffer in place — those count 2x the UPDATE region;
        * operands read only through slicing ops count just the slices
          (scan-over-layers weight indexing);
        * other operand reads are capped at the fusion's output size —
          a fused elementwise computation touches at most one input
          element per output element (lazy fusion evaluation) — UNLESS
          the fusion reduces, in which case inputs are read in full.
        """
        body = comps.get(called, [])
        symloc = {i.name: i for i in body}
        # output side
        out_b = float(ins.bytes_)
        dus_list = [i for i in body if i.opcode == "dynamic-update-slice"]
        for d in dus_list:
            ops = d.operand_names()
            upd = symloc.get(ops[1]) if len(ops) > 1 else None
            out_b -= d.bytes_
            out_b += 2.0 * (upd.bytes_ if upd is not None else 0.0)
        out_b = max(out_b, 0.0)
        has_reduce = any(i.opcode in ("reduce", "reduce-window")
                         for i in body)
        # inputs
        in_b = 0.0
        dus_targets = set()
        for d in dus_list:
            ops = d.operand_names()
            if ops:
                # follow elementwise chains back to the aliased buffer
                nm = ops[0]
                seen = 0
                while nm in symloc and seen < 8 and \
                        symloc[nm].opcode in ("convert", "bitcast", "copy",
                                              "reshape"):
                    nxt = symloc[nm].operand_names()
                    if not nxt:
                        break
                    nm = nxt[0]
                    seen += 1
                dus_targets.add(nm)
        for idx in range(len(ins.operand_names())):
            p = _param_instr(called, idx)
            if p is None:
                continue
            if p.name in dus_targets:
                continue                      # in-place buffer: no read
            uses = [i for i in body if p.name in i.operand_names()]
            if uses and all(u.opcode in ("dynamic-slice", "slice", "gather")
                            for u in uses):
                in_b += float(sum(u.bytes_ for u in uses))
            elif has_reduce:
                in_b += float(p.bytes_)
            else:
                in_b += float(min(p.bytes_, max(out_b, 1.0)))
        return out_b + in_b

    def cost_of(comp: str, at_top: bool):
        key = (comp, at_top)
        if key in memo:
            return memo[key]
        flops = 0.0
        byts = 0.0
        colls = {c: {"count": 0, "bytes": 0.0, "link_bytes": 0.0}
                 for c in _COLLECTIVES}
        symtab = {i.name: i for i in comps.get(comp, [])}
        for ins in comps.get(comp, []):
            op = ins.opcode
            called = _CALLS.findall(ins.rest)
            if op == "while":
                stats["n_while"] += 1
                tm = _TRIP.search(ins.rest)
                trip = int(tm.group(1)) if tm else 1
                if not tm:
                    stats["unknown"] += 1
                for c in called:
                    f2, b2, c2 = cost_of(c, at_top)
                    flops += trip * f2
                    byts += trip * b2
                    for k in _COLLECTIVES:
                        for fld in ("count", "bytes", "link_bytes"):
                            colls[k][fld] += trip * c2[k][fld]
                continue
            if op == "fusion":
                # flops from inside; bytes at the boundary only
                for c in called:
                    f2, _, c2 = cost_of(c, False)
                    flops += f2
                    for k in _COLLECTIVES:
                        for fld in ("count", "bytes", "link_bytes"):
                            colls[k][fld] += c2[k][fld]
                if at_top and called:
                    byts += _fusion_bytes(called[0], ins)
                continue
            if op in ("call", "conditional", "async-start"):
                for c in called:
                    f2, b2, c2 = cost_of(c, at_top)
                    flops += f2
                    byts += b2
                    for k in _COLLECTIVES:
                        for fld in ("count", "bytes", "link_bytes"):
                            colls[k][fld] += c2[k][fld]
                continue
            # collectives (match base op and -start variants)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                g = 1
                m = _GROUPS1.search(ins.rest)
                if m:
                    g = len(m.group(1).split(","))
                else:
                    m = _GROUPS2.search(ins.rest)
                    if m:
                        g = int(m.group(2))
                b = ins.bytes_
                if base == "all-reduce":
                    lb = 2 * b * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    lb = b * (g - 1)
                elif base == "collective-permute":
                    lb = b
                else:
                    lb = b * (g - 1) / max(g, 1)
                colls[base]["count"] += 1
                colls[base]["bytes"] += b
                colls[base]["link_bytes"] += lb
                if at_top:
                    byts += 2 * b
                continue
            # flops
            if op == "dot":
                k = 1
                cm = _CONTRACT.search(ins.rest)
                opnds = ins.operand_names()
                if cm and opnds and opnds[0] in symtab:
                    lhs = symtab[opnds[0]]
                    dims = []
                    for _, dd in _SHAPE_TOKEN.findall(lhs.shape_txt):
                        dims = [int(x) for x in dd.split(",") if x]
                        break
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
                flops += 2.0 * ins.elems * k
            elif op in ("convolution",):
                flops += 2.0 * ins.elems  # lower bound; convs are stubs here
            elif op in _TRANSCENDENTAL:
                flops += 4.0 * ins.elems
            elif op in _ELEMENTWISE:
                flops += 1.0 * ins.elems
            elif op in ("reduce", "reduce-window"):
                opnds = ins.operand_names()
                if opnds and opnds[0] in symtab:
                    flops += symtab[opnds[0]].elems
                else:
                    flops += ins.elems
            # bytes at fusion-boundary level, slice-aware
            if at_top and op not in _NO_BYTES:
                if op in ("dynamic-slice", "slice", "gather"):
                    byts += 2 * ins.bytes_         # read slice, write result
                elif op == "dynamic-update-slice":
                    ops = ins.operand_names()
                    upd = symtab.get(ops[1]) if len(ops) > 1 else None
                    byts += 2 * (upd.bytes_ if upd else ins.bytes_)
                elif op == "scatter":
                    ops = ins.operand_names()
                    upd = symtab.get(ops[2]) if len(ops) > 2 else None
                    byts += 3 * (upd.bytes_ if upd else ins.bytes_)
                else:
                    byts += ins.bytes_
                    for nm in ins.operand_names():
                        if nm in symtab:
                            byts += symtab[nm].bytes_
        memo[key] = (flops, byts, colls)
        return memo[key]

    flops, byts, colls = cost_of(entry, True)
    return HloCost(flops=flops, hbm_bytes=byts, collectives=colls,
                   n_while=stats["n_while"],
                   unknown_trip_loops=stats["unknown"])
